//! Per-node managers (paper §4, Figure 7).
//!
//! The **clone server** owns the clone-side process lifecycle: it
//! provisions a process forked from an independently-booted Zygote
//! template, keeps the synchronized file system, instantiates migrated
//! threads, drives them to their reintegration point, and ships them
//! home. The **phone-side manager** is the mobile device's stub: one
//! channel, provision/sync/migrate calls, byte accounting for the
//! network cost model.

use std::sync::Arc;

use crate::appvm::interp::RunExit;
use crate::appvm::natives::NodeEnv;
use crate::appvm::process::Process;
use crate::appvm::zygote::build_template;
use crate::appvm::{ExecTier, Program};
use crate::config::{CostParams, ExecTierKind};
use crate::device::{DeviceSpec, Location};
use crate::error::{CloneCloudError, Result};
use crate::migration::{collect_slot_garbage, Capsule, CloneSession, Migrator, MobileSession};
use crate::trace::{self, Counter, Endpoint, Phase, Tracer};
use crate::vfs::SimFs;

use super::protocol::{
    codec_agreed_at, delta_agreed_at, dict_agreed, open_frame, program_hash, seal_frame,
    trace_agreed, Codec, HeartbeatOutcome, Msg, PROTO_VERSION, SUPPORTED_CAPS,
};
use super::transport::Transport;
use crate::migration::{DictMode, DictRead};

/// Statistics from one clone-serving session.
#[derive(Debug, Clone, Default)]
pub struct CloneServeStats {
    /// Forward capsules executed to their reintegration point.
    pub migrations: usize,
    /// Instructions executed on behalf of migrated threads.
    pub instrs_executed: u64,
    /// Stale phone→clone object-map entries dropped at capture time.
    pub mapping_entries_dropped: usize,
    /// Migrations that arrived as delta capsules.
    pub delta_migrations: usize,
    /// Delta capsules rejected with `NeedFull` (missing/incoherent
    /// baseline); the phone re-sent them in full.
    pub delta_rejects: usize,
    /// Digest heartbeats answered.
    pub heartbeats: usize,
    /// Heartbeats answered `NeedFull` (divergent/missing baseline).
    pub heartbeat_divergent: usize,
    /// Periodic slot collections run.
    pub slot_gc_runs: usize,
    /// Tombstone threads reclaimed by slot GC.
    pub slot_gc_threads: usize,
    /// Orphaned object-graph copies reclaimed by slot GC.
    pub slot_gc_objects: usize,
    /// Tier-1 engine activity (zero when `exec_tier = interp`): methods
    /// promoted past the hotness threshold.
    pub tier_promotions: u64,
    /// Successful tier-1 translations.
    pub tier_translations: u64,
    /// Hot activations served from the translation cache.
    pub tier_cache_hits: u64,
    /// Instructions executed by translated tier-1 segments.
    pub tier1_instrs: u64,
    /// Scatter sub-job frames unwrapped and executed (one shard each).
    pub scatter_subjobs: u64,
}

/// The clone node: serves one phone over one transport.
pub struct CloneServer<T: Transport> {
    transport: T,
    program: Arc<Program>,
    device: DeviceSpec,
    costs: CostParams,
    make_env: Box<dyn Fn(SimFs) -> NodeEnv>,
    /// Interpreter fuel per offloaded span (guards runaway threads).
    pub fuel: u64,
    /// Run a slot garbage collection every this many migrations
    /// (0 = never): reclaims tombstone threads + orphaned object-graph
    /// copies without evicting the live delta baseline.
    pub slot_gc_interval: u64,
    /// Highest protocol revision this server speaks. Defaults to
    /// [`PROTO_VERSION`]; the interop matrix pins it lower to emulate a
    /// frozen responder build.
    pub proto_cap: u16,
    /// Capability bitmap this server advertises (defaults to
    /// [`SUPPORTED_CAPS`]; mask bits off for ablations/skew tests).
    pub local_caps: u32,
    /// Whether this server offers delta capsules at all.
    pub speak_delta: bool,
    /// Clone-side flight recorder. Disabled by default; a forward
    /// capsule carrying a trace context still gets its events recorded
    /// (and shipped back) via an ephemeral per-trip recorder inside
    /// [`execute_migration`], so this field is for server-local
    /// observability beyond single trips.
    pub tracer: Tracer,
    /// Execution tier for offloaded spans (default tier 1; the
    /// `exec_tier = "interp"` ablation selects the switch interpreter).
    pub tier: ExecTier,
}

impl<T: Transport> CloneServer<T> {
    /// Build a server for one transport with default tuning (tier-1
    /// execution, full protocol revision and capability set).
    pub fn new(
        transport: T,
        program: Arc<Program>,
        costs: CostParams,
        make_env: Box<dyn Fn(SimFs) -> NodeEnv>,
    ) -> CloneServer<T> {
        CloneServer {
            transport,
            program,
            device: DeviceSpec::clone_desktop(),
            costs,
            make_env,
            fuel: 2_000_000_000,
            slot_gc_interval: 8,
            proto_cap: PROTO_VERSION,
            local_caps: SUPPORTED_CAPS,
            speak_delta: true,
            tracer: Tracer::disabled(),
            tier: ExecTier::from_kind(ExecTierKind::default()),
        }
    }

    /// Select the execution tier for offloaded spans.
    pub fn with_exec_tier(mut self, kind: ExecTierKind) -> Self {
        self.tier = ExecTier::from_kind(kind);
        self
    }

    /// Serve until Shutdown (or transport loss). Each Migrate is answered
    /// with a Reintegrate carrying the reverse capture.
    pub fn serve(mut self) -> Result<CloneServeStats> {
        let mut stats = CloneServeStats::default();
        let mut fs = SimFs::new();
        let mut proc: Option<Process> = None;
        // Delta and compression stay off until the phone's Hello.
        let mut session = CloneSession::new(false);
        let mut codec = Codec::None;
        let mut roundtrips = 0u64;
        let migrator = Migrator::new(self.costs.clone());

        loop {
            let (msg, _) = self.transport.recv()?;
            match msg {
                Msg::Hello { proto, delta, caps } => {
                    let speak_delta =
                        self.speak_delta && delta_agreed_at(self.proto_cap, proto, delta);
                    codec = codec_agreed_at(self.proto_cap, self.local_caps, proto, caps);
                    session.set_enabled(speak_delta);
                    session.set_dict_enabled(dict_agreed(
                        self.proto_cap,
                        self.local_caps,
                        proto,
                        caps,
                    ));
                    // Reply with the negotiated (min) revision so a v3
                    // initiator gets a Hello its decoder accepts (the
                    // caps field only rides when that revision is >= 4).
                    self.transport.send(&Msg::Hello {
                        proto: proto.min(self.proto_cap),
                        delta: speak_delta,
                        caps: self.local_caps,
                    })?;
                }
                Msg::Provision {
                    zygote_objects,
                    zygote_seed,
                    program_hash: want,
                } => {
                    let have = program_hash(&self.program);
                    if have != want {
                        self.transport.send(&Msg::Error(format!(
                            "program hash mismatch: clone={have:#x} phone={want:#x} (resync executables)"
                        )))?;
                        continue;
                    }
                    // Independent Zygote boot (same parameters => same
                    // (class, seq) names — §4.3).
                    let template =
                        build_template(&self.program, zygote_objects as usize, zygote_seed);
                    let mut p = Process::fork_from_zygote(
                        self.program.clone(),
                        &template,
                        self.device.clone(),
                        Location::Clone,
                        (self.make_env)(fs.synchronize()),
                    );
                    p.cost_params = Some(self.costs.clone());
                    proc = Some(p);
                    self.transport.send(&Msg::Ack)?;
                }
                Msg::SyncFs(newfs) => {
                    fs = newfs;
                    if let Some(p) = proc.as_mut() {
                        p.env.vfs = fs.synchronize();
                    }
                    self.transport.send(&Msg::Ack)?;
                }
                Msg::Migrate(bytes) => {
                    // Frame layer: the payload may arrive sealed
                    // (compressed); the reply is sealed under the
                    // negotiated codec.
                    let reply = open_frame(&bytes).and_then(|raw| {
                        self.handle_migration(
                            &migrator,
                            proc.as_mut(),
                            &raw,
                            &mut stats,
                            &mut session,
                        )
                    });
                    match reply {
                        Ok(rbytes) => {
                            roundtrips += 1;
                            if self.slot_gc_interval > 0
                                && roundtrips % self.slot_gc_interval == 0
                            {
                                if let Some(p) = proc.as_mut() {
                                    let gc = collect_slot_garbage(p, &session);
                                    stats.slot_gc_runs += 1;
                                    stats.slot_gc_threads += gc.threads_reclaimed;
                                    stats.slot_gc_objects += gc.objects_reclaimed;
                                }
                            }
                            self.transport
                                .send(&Msg::Reintegrate(seal_frame(codec, rbytes)))?
                        }
                        Err(CloneCloudError::NeedFull(reason)) => {
                            stats.delta_rejects += 1;
                            self.transport.send(&Msg::NeedFull(reason))?
                        }
                        Err(e) => self.transport.send(&Msg::Error(e.to_string()))?,
                    };
                }
                Msg::Heartbeat {
                    base_epoch: _,
                    digest,
                    assignments,
                } => {
                    stats.heartbeats += 1;
                    let res = match proc.as_ref() {
                        Some(p) => session.check_heartbeat(p, digest, &assignments),
                        None => Err(CloneCloudError::need_full("heartbeat before provision")),
                    };
                    match res {
                        Ok(()) => self.transport.send(&Msg::Ack)?,
                        Err(e) if e.is_need_full() => {
                            stats.heartbeat_divergent += 1;
                            // Covers the provision-less probe too: any
                            // NeedFull leaving this server resets the
                            // dictionary replica (idempotent when
                            // `check_heartbeat` already did).
                            session.reset_dict();
                            self.transport.send(&Msg::NeedFull(e.to_string()))?
                        }
                        Err(e) => self.transport.send(&Msg::Error(e.to_string()))?,
                    };
                }
                Msg::Shutdown => return Ok(stats),
                other => {
                    self.transport
                        .send(&Msg::Error(format!("unexpected message {other:?}")))?;
                }
            }
        }
    }

    fn handle_migration(
        &mut self,
        migrator: &Migrator,
        proc: Option<&mut Process>,
        bytes: &[u8],
        stats: &mut CloneServeStats,
        session: &mut CloneSession,
    ) -> Result<Vec<u8>> {
        let p = proc.ok_or_else(|| CloneCloudError::Transport("migrate before provision".into()))?;
        execute_migration(
            migrator,
            p,
            bytes,
            self.fuel,
            stats,
            session,
            &mut self.tracer,
            &mut self.tier,
        )
    }
}

/// Execute one forward capsule on a clone process and return the encoded
/// reverse capsule. This is the clone-side inner loop shared by the
/// single-phone [`CloneServer`] and the multi-tenant farm workers
/// (`farm::worker`): decode (full capture or delta against the session
/// baseline), instantiate, drive to the reintegration point, capture
/// back (as a delta when the session negotiated it).
///
/// A `NeedFull` error means the delta could not be applied (no baseline /
/// digest mismatch); the caller relays it so the phone re-sends in full.
///
/// Tracing: a forward payload may carry a self-describing trace-context
/// envelope (`CAP_TRACE_CTX`). When present, clone-side phase spans are
/// recorded — into `tracer` if the caller enabled one, else into an
/// ephemeral per-trip recorder — and piggybacked in front of the reverse
/// capsule when the context asks for them. Observe-only: the envelope
/// never changes what executes.
///
/// `tier` selects the execution engine for the offloaded span (the
/// caller owns it so profile state and the translation cache persist
/// across roundtrips of one slot). Tier 1 is bit-identical to the
/// interpreter — results, virtual-time charges, and exit points cannot
/// depend on the tier.
#[allow(clippy::too_many_arguments)]
pub fn execute_migration(
    migrator: &Migrator,
    p: &mut Process,
    bytes: &[u8],
    fuel: u64,
    stats: &mut CloneServeStats,
    session: &mut CloneSession,
    tracer: &mut Tracer,
    tier: &mut ExecTier,
) -> Result<Vec<u8>> {
    // Scatter sub-job frames (`CAP_SCATTER`): unwrap, execute the inner
    // capsule exactly like a plain `Migrate` payload, and tag the reply
    // with the shard index so the gather side can match it. Living here
    // — the one execution core — is what keeps the sub-job framing
    // identical across the blocking gateway, the async gateway, the
    // single-phone server, and the farm workers (one-protocol
    // invariant).
    if super::protocol::is_sub_job(bytes) {
        let sub = super::protocol::decode_sub_job(bytes)?;
        stats.scatter_subjobs += 1;
        let reply = execute_migration(
            migrator,
            p,
            &sub.payload,
            fuel,
            stats,
            session,
            tracer,
            tier,
        )?;
        return Ok(super::protocol::encode_sub_result(sub.shard, &reply));
    }

    let (ctx, bytes) = trace::split_ctx(bytes)?;
    let mut ephemeral;
    let tracer: &mut Tracer = match ctx {
        Some(c) if !tracer.is_enabled() => {
            ephemeral = Tracer::new(c.session_id, Endpoint::Clone, 256);
            &mut ephemeral
        }
        _ => tracer,
    };
    let trip = ctx.map(|c| c.trip).unwrap_or(0);
    let mark = tracer.mark();

    // Session dictionary: decode against the slot replica when the
    // session negotiated it (a prefix-digest mismatch resets the replica
    // and surfaces as `NeedFull` right here), and answer the reverse
    // capsule in the same mode the forward one rode — so a peer that
    // fell back to the inline table never sees a dictionary reply.
    let wall0 = std::time::Instant::now();
    let (capsule, used_dict) = if session.dict_enabled() {
        Capsule::decode_with(bytes, DictRead::Negotiated(session.dict()))?
    } else {
        (Capsule::decode(bytes)?, false)
    };
    let is_delta = capsule.is_delta();
    let decode_wall = wall0.elapsed().as_micros() as u64;
    let wall0 = std::time::Instant::now();
    let (tid, _) = migrator.receive_capsule_at_clone(p, &capsule, session)?;
    // The merge installed the capsule's shipped virtual clock, so the
    // arrival stamp is only known now; decode/merge are not charged to
    // virtual time, so they sit at that point with measured wall widths.
    let t_arrival = p.clock.now_us();
    tracer.span_wall(trip, Phase::CloneDecode, t_arrival, decode_wall);
    tracer.span_wall(
        trip,
        Phase::CloneMerge,
        t_arrival,
        wall0.elapsed().as_micros() as u64,
    );
    let instrs0 = p.metrics.instrs;

    // Drive the migrant to its reintegration point. Nested CcStart
    // means "already at the clone — continue" (Property 3 guarantees
    // migration/reintegration alternate).
    tracer.begin(trip, Phase::CloneExec, t_arrival);
    loop {
        match tier.run_thread(p, tid, fuel)? {
            RunExit::ReintegrationPoint { .. } => break,
            RunExit::MigrationPoint { .. } => continue,
            RunExit::Completed(_) => {
                return Err(CloneCloudError::migration(
                    "offloaded thread completed without a reintegration point",
                ))
            }
            RunExit::OutOfFuel => {
                return Err(CloneCloudError::migration("clone execution out of fuel"))
            }
        }
    }
    tracer.end(trip, Phase::CloneExec, p.clock.now_us());
    let tstats = tier.take_stats();
    stats.tier_promotions += tstats.promotions;
    stats.tier_translations += tstats.translations;
    stats.tier_cache_hits += tstats.cache_hits;
    stats.tier1_instrs += tstats.tier1_instrs;
    if tstats.translation_wall_us > 0 {
        // Translation is runtime work inside the exec window: wall time
        // only, no virtual charge (same convention as decode/merge).
        tracer.span_wall(trip, Phase::Tier, p.clock.now_us(), tstats.translation_wall_us);
    }
    stats.migrations += 1;
    if is_delta {
        stats.delta_migrations += 1;
    }
    stats.instrs_executed += p.metrics.instrs - instrs0;
    tracer.counter(
        trip,
        Counter::Instrs,
        (p.metrics.instrs - instrs0) as f64,
        p.clock.now_us(),
    );
    let wall0 = std::time::Instant::now();
    let (rcapsule, _, dropped) = migrator.return_capsule_from_clone(p, tid, session)?;
    stats.mapping_entries_dropped += dropped;
    tracer.span_wall(
        trip,
        Phase::CloneCapture,
        p.clock.now_us(),
        wall0.elapsed().as_micros() as u64,
    );
    let wall0 = std::time::Instant::now();
    let encoded = if session.dict_enabled() {
        if used_dict {
            rcapsule.encode_with(DictMode::Shared(session.dict()))?
        } else {
            rcapsule.encode_with(DictMode::Inline)?
        }
    } else {
        rcapsule.encode()?
    };
    tracer.span_wall(
        trip,
        Phase::CloneEncode,
        p.clock.now_us(),
        wall0.elapsed().as_micros() as u64,
    );
    match ctx {
        Some(c) if c.wants_clone_events() => {
            trace::prepend_events(&tracer.events_since(mark), &encoded)
        }
        _ => Ok(encoded),
    }
}

/// Byte accounting for one migration round trip.
#[derive(Debug, Clone, Copy, Default)]
pub struct TransferBytes {
    /// Bytes shipped phone → clone (forward capsule, fs sync).
    pub up: u64,
    /// Bytes shipped clone → phone (reverse capsule).
    pub down: u64,
}

/// The phone-side node manager.
pub struct NodeManager<T: Transport> {
    transport: T,
    /// Cumulative bytes moved (metrics).
    pub total: TransferBytes,
    /// Set by [`NodeManager::negotiate`]: both peers speak delta.
    delta_negotiated: bool,
    /// Set by [`NodeManager::negotiate`]: the agreed frame codec.
    codec: Codec,
    /// Set by [`NodeManager::negotiate`]: both peers keep the session
    /// string dictionary.
    dict_negotiated: bool,
    /// Set by [`NodeManager::negotiate`]: both peers understand the
    /// trace-context envelope.
    trace_negotiated: bool,
    /// Set by [`NodeManager::negotiate`]: both peers understand scatter
    /// sub-job frames.
    scatter_negotiated: bool,
    /// The peer's protocol revision from its `Hello` (0 = never seen).
    peer_proto: u16,
    /// The revision/caps/delta this endpoint advertises. Default to the
    /// build's; the interop matrix pins them to emulate older builds.
    local_proto: u16,
    local_caps: u32,
    local_delta: bool,
}

impl<T: Transport> NodeManager<T> {
    /// Wrap a connected transport; no negotiation happens until
    /// [`NodeManager::negotiate`].
    pub fn new(transport: T) -> NodeManager<T> {
        NodeManager {
            transport,
            total: TransferBytes::default(),
            delta_negotiated: false,
            codec: Codec::None,
            dict_negotiated: false,
            trace_negotiated: false,
            scatter_negotiated: false,
            peer_proto: 0,
            local_proto: PROTO_VERSION,
            local_caps: SUPPORTED_CAPS,
            local_delta: true,
        }
    }

    /// Pin the revision this endpoint claims in its `Hello` (skew
    /// testing: a pre-v4 initiator sends the caps-less Hello shape).
    pub fn pretend_proto(&mut self, proto: u16) {
        self.local_proto = proto;
    }

    /// Override the capability bitmap this endpoint advertises.
    pub fn advertise_caps(&mut self, caps: u32) {
        self.local_caps = caps;
    }

    /// Whether this endpoint offers delta capsules in its `Hello`.
    pub fn advertise_delta(&mut self, on: bool) {
        self.local_delta = on;
    }

    /// Negotiate protocol capabilities. Returns whether delta capsules
    /// may flow on this channel (the frame codec lands in
    /// [`NodeManager::negotiated_codec`]); a peer that answers `Error`
    /// (pre-v3) is treated as full-capture-only rather than a failure.
    pub fn negotiate(&mut self) -> Result<bool> {
        self.transport.send(&Msg::Hello {
            proto: self.local_proto,
            delta: self.local_delta,
            // Pre-v4 Hellos have no caps field on the wire; keep the
            // in-memory value consistent with what actually rides.
            caps: if self.local_proto >= super::protocol::COMPRESS_MIN_PROTO {
                self.local_caps
            } else {
                0
            },
        })?;
        match self.transport.recv()?.0 {
            Msg::Hello { proto, delta, caps } => {
                self.peer_proto = proto;
                self.delta_negotiated =
                    self.local_delta && delta_agreed_at(self.local_proto, proto, delta);
                self.codec = codec_agreed_at(self.local_proto, self.local_caps, proto, caps);
                self.dict_negotiated =
                    dict_agreed(self.local_proto, self.local_caps, proto, caps);
                self.trace_negotiated =
                    trace_agreed(self.local_proto, self.local_caps, proto, caps);
                self.scatter_negotiated = super::protocol::scatter_agreed(
                    self.local_proto,
                    self.local_caps,
                    proto,
                    caps,
                );
            }
            // A peer that answers Error instead of Hello doesn't do
            // capability negotiation; stay on full, uncompressed frames.
            // (A peer so old it can't even *decode* Hello drops the
            // transport, which surfaces as the recv error above —
            // callers treat a failed negotiation as fatal for the
            // connection, as they should.)
            Msg::Error(_) => {
                self.delta_negotiated = false;
                self.codec = Codec::None;
                self.dict_negotiated = false;
                self.trace_negotiated = false;
                self.scatter_negotiated = false;
            }
            other => {
                return Err(CloneCloudError::Transport(format!(
                    "expected Hello, got {other:?}"
                )))
            }
        };
        Ok(self.delta_negotiated)
    }

    /// Whether [`NodeManager::negotiate`] agreed on the session string
    /// dictionary.
    pub fn dict_negotiated(&self) -> bool {
        self.dict_negotiated
    }

    /// Whether [`NodeManager::negotiate`] agreed on delta capsules.
    pub fn delta_negotiated(&self) -> bool {
        self.delta_negotiated
    }

    /// Whether [`NodeManager::negotiate`] agreed on the trace-context
    /// envelope (`CAP_TRACE_CTX`).
    pub fn trace_negotiated(&self) -> bool {
        self.trace_negotiated
    }

    /// Whether [`NodeManager::negotiate`] agreed on scatter sub-job
    /// frames (`CAP_SCATTER`).
    pub fn scatter_negotiated(&self) -> bool {
        self.scatter_negotiated
    }

    /// The frame codec [`NodeManager::negotiate`] agreed on.
    pub fn negotiated_codec(&self) -> Codec {
        self.codec
    }

    /// The protocol revision this session effectively speaks (the
    /// min-version agreement; the local revision before any `Hello`).
    pub fn negotiated_proto(&self) -> u16 {
        if self.peer_proto == 0 {
            self.local_proto
        } else {
            self.peer_proto.min(self.local_proto)
        }
    }

    /// Re-Hello the peer with `delta = false` (the driver's session
    /// cannot merge reverse deltas, so the clone must stop emitting
    /// them). The codec survives — compression is orthogonal to deltas.
    /// Best effort: a transport failure here will resurface on the next
    /// real call anyway.
    pub fn renegotiate_off(&mut self) {
        if !self.delta_negotiated {
            return;
        }
        self.delta_negotiated = false;
        let sent = self.transport.send(&Msg::Hello {
            proto: self.local_proto,
            delta: false,
            caps: if self.local_proto >= super::protocol::COMPRESS_MIN_PROTO {
                self.local_caps
            } else {
                0
            },
        });
        if sent.is_ok() {
            let _ = self.transport.recv(); // consume the peer's Hello reply
        }
    }

    /// Probe the clone's session baseline with a digest-only heartbeat
    /// (plus any pending MID assignments). `Divergent` means the clone
    /// answered `NeedFull`: the local baseline is dropped here, so the
    /// next capture goes out full instead of as a doomed delta.
    pub fn heartbeat(&mut self, sess: &mut MobileSession) -> Result<HeartbeatOutcome> {
        // Heartbeat is a v4 frame: never send it to a peer whose
        // negotiated revision cannot decode it (tag error would kill
        // the whole session, not just the probe). Delta negotiation
        // already implies v4 (`DELTA_MIN_PROTO`), so this is
        // belt-and-braces against future skew in either constant.
        if !self.delta_negotiated
            || self.negotiated_proto() < super::protocol::COMPRESS_MIN_PROTO
        {
            return Ok(HeartbeatOutcome::Unsupported);
        }
        let transport = &mut self.transport;
        super::protocol::drive_heartbeat(sess, |base_epoch, digest, assignments| {
            transport.send(&Msg::Heartbeat {
                base_epoch,
                digest,
                assignments: assignments.to_vec(),
            })?;
            match transport.recv()?.0 {
                Msg::Ack => Ok(()),
                Msg::NeedFull(reason) => Err(CloneCloudError::NeedFull(reason)),
                Msg::Error(e) => Err(CloneCloudError::Transport(format!("clone error: {e}"))),
                other => Err(CloneCloudError::Transport(format!(
                    "expected heartbeat reply, got {other:?}"
                ))),
            }
        })
    }

    fn expect_ack(&mut self) -> Result<()> {
        match self.transport.recv()?.0 {
            Msg::Ack => Ok(()),
            Msg::Error(e) => Err(CloneCloudError::Transport(format!("clone error: {e}"))),
            other => Err(CloneCloudError::Transport(format!("expected Ack, got {other:?}"))),
        }
    }

    /// Provision the clone (Zygote boot + executable identity check).
    pub fn provision(
        &mut self,
        program: &Program,
        zygote_objects: usize,
        zygote_seed: u64,
    ) -> Result<()> {
        self.transport.send(&Msg::Provision {
            zygote_objects: zygote_objects as u32,
            zygote_seed,
            program_hash: program_hash(program),
        })?;
        self.expect_ack()
    }

    /// Synchronize the file system image; returns bytes moved.
    pub fn sync_fs(&mut self, fs: &SimFs) -> Result<u64> {
        let n = self.transport.send(&Msg::SyncFs(fs.synchronize()))?;
        self.expect_ack()?;
        Ok(n)
    }

    /// One migration round trip: ship the forward capture, block for the
    /// reverse capture. Returns (reverse packet bytes, byte accounting).
    pub fn migrate(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        let up = self.transport.send(&Msg::Migrate(forward))?;
        let (msg, down) = self.transport.recv()?;
        let bytes = match msg {
            Msg::Reintegrate(b) => b,
            Msg::NeedFull(reason) => {
                // Typed, recoverable: the driver re-captures in full.
                self.total.up += up;
                return Err(CloneCloudError::NeedFull(reason));
            }
            Msg::Error(e) => {
                return Err(CloneCloudError::Transport(format!("clone error: {e}")))
            }
            other => {
                return Err(CloneCloudError::Transport(format!(
                    "expected Reintegrate, got {other:?}"
                )))
            }
        };
        let t = TransferBytes { up, down };
        self.total.up += up;
        self.total.down += down;
        Ok((bytes, t))
    }

    /// Tell the peer this session is over (clean EOF follows).
    pub fn shutdown(&mut self) -> Result<()> {
        self.transport.send(&Msg::Shutdown)?;
        Ok(())
    }
}
