//! Per-node managers (paper §4, Figure 7).
//!
//! The **clone server** owns the clone-side process lifecycle: it
//! provisions a process forked from an independently-booted Zygote
//! template, keeps the synchronized file system, instantiates migrated
//! threads, drives them to their reintegration point, and ships them
//! home. The **phone-side manager** is the mobile device's stub: one
//! channel, provision/sync/migrate calls, byte accounting for the
//! network cost model.

use std::sync::Arc;

use crate::appvm::interp::{run_thread, NoHooks, RunExit};
use crate::appvm::natives::NodeEnv;
use crate::appvm::process::Process;
use crate::appvm::zygote::build_template;
use crate::appvm::Program;
use crate::config::CostParams;
use crate::device::{DeviceSpec, Location};
use crate::error::{CloneCloudError, Result};
use crate::migration::{CapturePacket, Migrator};
use crate::vfs::SimFs;

use super::protocol::{program_hash, Msg};
use super::transport::Transport;

/// Statistics from one clone-serving session.
#[derive(Debug, Clone, Default)]
pub struct CloneServeStats {
    pub migrations: usize,
    pub instrs_executed: u64,
    pub mapping_entries_dropped: usize,
}

/// The clone node: serves one phone over one transport.
pub struct CloneServer<T: Transport> {
    transport: T,
    program: Arc<Program>,
    device: DeviceSpec,
    costs: CostParams,
    make_env: Box<dyn Fn(SimFs) -> NodeEnv>,
    /// Interpreter fuel per offloaded span (guards runaway threads).
    pub fuel: u64,
}

impl<T: Transport> CloneServer<T> {
    pub fn new(
        transport: T,
        program: Arc<Program>,
        costs: CostParams,
        make_env: Box<dyn Fn(SimFs) -> NodeEnv>,
    ) -> CloneServer<T> {
        CloneServer {
            transport,
            program,
            device: DeviceSpec::clone_desktop(),
            costs,
            make_env,
            fuel: 2_000_000_000,
        }
    }

    /// Serve until Shutdown (or transport loss). Each Migrate is answered
    /// with a Reintegrate carrying the reverse capture.
    pub fn serve(mut self) -> Result<CloneServeStats> {
        let mut stats = CloneServeStats::default();
        let mut fs = SimFs::new();
        let mut proc: Option<Process> = None;
        let migrator = Migrator::new(self.costs.clone());

        loop {
            let (msg, _) = self.transport.recv()?;
            match msg {
                Msg::Provision {
                    zygote_objects,
                    zygote_seed,
                    program_hash: want,
                } => {
                    let have = program_hash(&self.program);
                    if have != want {
                        self.transport.send(&Msg::Error(format!(
                            "program hash mismatch: clone={have:#x} phone={want:#x} (resync executables)"
                        )))?;
                        continue;
                    }
                    // Independent Zygote boot (same parameters => same
                    // (class, seq) names — §4.3).
                    let template =
                        build_template(&self.program, zygote_objects as usize, zygote_seed);
                    let mut p = Process::fork_from_zygote(
                        self.program.clone(),
                        &template,
                        self.device.clone(),
                        Location::Clone,
                        (self.make_env)(fs.synchronize()),
                    );
                    p.cost_params = Some(self.costs.clone());
                    proc = Some(p);
                    self.transport.send(&Msg::Ack)?;
                }
                Msg::SyncFs(newfs) => {
                    fs = newfs;
                    if let Some(p) = proc.as_mut() {
                        p.env.vfs = fs.synchronize();
                    }
                    self.transport.send(&Msg::Ack)?;
                }
                Msg::Migrate(bytes) => {
                    let reply = self.handle_migration(&migrator, proc.as_mut(), &bytes, &mut stats);
                    match reply {
                        Ok(rbytes) => self.transport.send(&Msg::Reintegrate(rbytes))?,
                        Err(e) => self.transport.send(&Msg::Error(e.to_string()))?,
                    };
                }
                Msg::Shutdown => return Ok(stats),
                other => {
                    self.transport
                        .send(&Msg::Error(format!("unexpected message {other:?}")))?;
                }
            }
        }
    }

    fn handle_migration(
        &self,
        migrator: &Migrator,
        proc: Option<&mut Process>,
        bytes: &[u8],
        stats: &mut CloneServeStats,
    ) -> Result<Vec<u8>> {
        let p = proc.ok_or_else(|| CloneCloudError::Transport("migrate before provision".into()))?;
        execute_migration(migrator, p, bytes, self.fuel, stats)
    }
}

/// Execute one forward capture on a clone process and return the encoded
/// reverse capture. This is the clone-side inner loop shared by the
/// single-phone [`CloneServer`] and the multi-tenant farm workers
/// (`farm::worker`): decode, instantiate, drive to the reintegration
/// point, capture back.
pub fn execute_migration(
    migrator: &Migrator,
    p: &mut Process,
    bytes: &[u8],
    fuel: u64,
    stats: &mut CloneServeStats,
) -> Result<Vec<u8>> {
    let packet = CapturePacket::decode(bytes)?;
    let (tid, table, _) = migrator.receive_at_clone(p, &packet)?;
    let instrs0 = p.metrics.instrs;

    // Drive the migrant to its reintegration point. Nested CcStart
    // means "already at the clone — continue" (Property 3 guarantees
    // migration/reintegration alternate).
    loop {
        match run_thread(p, tid, &mut NoHooks, fuel)? {
            RunExit::ReintegrationPoint { .. } => break,
            RunExit::MigrationPoint { .. } => continue,
            RunExit::Completed(_) => {
                return Err(CloneCloudError::migration(
                    "offloaded thread completed without a reintegration point",
                ))
            }
            RunExit::OutOfFuel => {
                return Err(CloneCloudError::migration("clone execution out of fuel"))
            }
        }
    }
    stats.migrations += 1;
    stats.instrs_executed += p.metrics.instrs - instrs0;
    let (rpacket, _, dropped) = migrator.return_from_clone(p, tid, table)?;
    stats.mapping_entries_dropped += dropped;
    Ok(rpacket.encode())
}

/// Byte accounting for one migration round trip.
#[derive(Debug, Clone, Copy, Default)]
pub struct TransferBytes {
    pub up: u64,
    pub down: u64,
}

/// The phone-side node manager.
pub struct NodeManager<T: Transport> {
    transport: T,
    /// Cumulative bytes moved (metrics).
    pub total: TransferBytes,
}

impl<T: Transport> NodeManager<T> {
    pub fn new(transport: T) -> NodeManager<T> {
        NodeManager {
            transport,
            total: TransferBytes::default(),
        }
    }

    fn expect_ack(&mut self) -> Result<()> {
        match self.transport.recv()?.0 {
            Msg::Ack => Ok(()),
            Msg::Error(e) => Err(CloneCloudError::Transport(format!("clone error: {e}"))),
            other => Err(CloneCloudError::Transport(format!("expected Ack, got {other:?}"))),
        }
    }

    /// Provision the clone (Zygote boot + executable identity check).
    pub fn provision(
        &mut self,
        program: &Program,
        zygote_objects: usize,
        zygote_seed: u64,
    ) -> Result<()> {
        self.transport.send(&Msg::Provision {
            zygote_objects: zygote_objects as u32,
            zygote_seed,
            program_hash: program_hash(program),
        })?;
        self.expect_ack()
    }

    /// Synchronize the file system image; returns bytes moved.
    pub fn sync_fs(&mut self, fs: &SimFs) -> Result<u64> {
        let n = self.transport.send(&Msg::SyncFs(fs.synchronize()))?;
        self.expect_ack()?;
        Ok(n)
    }

    /// One migration round trip: ship the forward capture, block for the
    /// reverse capture. Returns (reverse packet bytes, byte accounting).
    pub fn migrate(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        let up = self.transport.send(&Msg::Migrate(forward))?;
        let (msg, down) = self.transport.recv()?;
        let bytes = match msg {
            Msg::Reintegrate(b) => b,
            Msg::Error(e) => {
                return Err(CloneCloudError::Transport(format!("clone error: {e}")))
            }
            other => {
                return Err(CloneCloudError::Transport(format!(
                    "expected Reintegrate, got {other:?}"
                )))
            }
        };
        let t = TransferBytes { up, down };
        self.total.up += up;
        self.total.down += down;
        Ok((bytes, t))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.transport.send(&Msg::Shutdown)?;
        Ok(())
    }
}
