//! Per-node managers (paper §4, Figure 7).
//!
//! The **clone server** owns the clone-side process lifecycle: it
//! provisions a process forked from an independently-booted Zygote
//! template, keeps the synchronized file system, instantiates migrated
//! threads, drives them to their reintegration point, and ships them
//! home. The **phone-side manager** is the mobile device's stub: one
//! channel, provision/sync/migrate calls, byte accounting for the
//! network cost model.

use std::sync::Arc;

use crate::appvm::interp::{run_thread, NoHooks, RunExit};
use crate::appvm::natives::NodeEnv;
use crate::appvm::process::Process;
use crate::appvm::zygote::build_template;
use crate::appvm::Program;
use crate::config::CostParams;
use crate::device::{DeviceSpec, Location};
use crate::error::{CloneCloudError, Result};
use crate::migration::{Capsule, CloneSession, Migrator};
use crate::vfs::SimFs;

use super::protocol::{program_hash, Msg, PROTO_VERSION};
use super::transport::Transport;

/// Statistics from one clone-serving session.
#[derive(Debug, Clone, Default)]
pub struct CloneServeStats {
    pub migrations: usize,
    pub instrs_executed: u64,
    pub mapping_entries_dropped: usize,
    /// Migrations that arrived as delta capsules.
    pub delta_migrations: usize,
    /// Delta capsules rejected with `NeedFull` (missing/incoherent
    /// baseline); the phone re-sent them in full.
    pub delta_rejects: usize,
}

/// The clone node: serves one phone over one transport.
pub struct CloneServer<T: Transport> {
    transport: T,
    program: Arc<Program>,
    device: DeviceSpec,
    costs: CostParams,
    make_env: Box<dyn Fn(SimFs) -> NodeEnv>,
    /// Interpreter fuel per offloaded span (guards runaway threads).
    pub fuel: u64,
}

impl<T: Transport> CloneServer<T> {
    pub fn new(
        transport: T,
        program: Arc<Program>,
        costs: CostParams,
        make_env: Box<dyn Fn(SimFs) -> NodeEnv>,
    ) -> CloneServer<T> {
        CloneServer {
            transport,
            program,
            device: DeviceSpec::clone_desktop(),
            costs,
            make_env,
            fuel: 2_000_000_000,
        }
    }

    /// Serve until Shutdown (or transport loss). Each Migrate is answered
    /// with a Reintegrate carrying the reverse capture.
    pub fn serve(mut self) -> Result<CloneServeStats> {
        let mut stats = CloneServeStats::default();
        let mut fs = SimFs::new();
        let mut proc: Option<Process> = None;
        // Delta stays off until the phone negotiates it via Hello.
        let mut session = CloneSession::new(false);
        let migrator = Migrator::new(self.costs.clone());

        loop {
            let (msg, _) = self.transport.recv()?;
            match msg {
                Msg::Hello { proto, delta } => {
                    let speak_delta = super::protocol::delta_agreed(proto, delta);
                    session.set_enabled(speak_delta);
                    self.transport.send(&Msg::Hello {
                        proto: PROTO_VERSION,
                        delta: speak_delta,
                    })?;
                }
                Msg::Provision {
                    zygote_objects,
                    zygote_seed,
                    program_hash: want,
                } => {
                    let have = program_hash(&self.program);
                    if have != want {
                        self.transport.send(&Msg::Error(format!(
                            "program hash mismatch: clone={have:#x} phone={want:#x} (resync executables)"
                        )))?;
                        continue;
                    }
                    // Independent Zygote boot (same parameters => same
                    // (class, seq) names — §4.3).
                    let template =
                        build_template(&self.program, zygote_objects as usize, zygote_seed);
                    let mut p = Process::fork_from_zygote(
                        self.program.clone(),
                        &template,
                        self.device.clone(),
                        Location::Clone,
                        (self.make_env)(fs.synchronize()),
                    );
                    p.cost_params = Some(self.costs.clone());
                    proc = Some(p);
                    self.transport.send(&Msg::Ack)?;
                }
                Msg::SyncFs(newfs) => {
                    fs = newfs;
                    if let Some(p) = proc.as_mut() {
                        p.env.vfs = fs.synchronize();
                    }
                    self.transport.send(&Msg::Ack)?;
                }
                Msg::Migrate(bytes) => {
                    let reply = self.handle_migration(
                        &migrator,
                        proc.as_mut(),
                        &bytes,
                        &mut stats,
                        &mut session,
                    );
                    match reply {
                        Ok(rbytes) => self.transport.send(&Msg::Reintegrate(rbytes))?,
                        Err(CloneCloudError::NeedFull(reason)) => {
                            stats.delta_rejects += 1;
                            self.transport.send(&Msg::NeedFull(reason))?
                        }
                        Err(e) => self.transport.send(&Msg::Error(e.to_string()))?,
                    };
                }
                Msg::Shutdown => return Ok(stats),
                other => {
                    self.transport
                        .send(&Msg::Error(format!("unexpected message {other:?}")))?;
                }
            }
        }
    }

    fn handle_migration(
        &self,
        migrator: &Migrator,
        proc: Option<&mut Process>,
        bytes: &[u8],
        stats: &mut CloneServeStats,
        session: &mut CloneSession,
    ) -> Result<Vec<u8>> {
        let p = proc.ok_or_else(|| CloneCloudError::Transport("migrate before provision".into()))?;
        execute_migration(migrator, p, bytes, self.fuel, stats, session)
    }
}

/// Execute one forward capsule on a clone process and return the encoded
/// reverse capsule. This is the clone-side inner loop shared by the
/// single-phone [`CloneServer`] and the multi-tenant farm workers
/// (`farm::worker`): decode (full capture or delta against the session
/// baseline), instantiate, drive to the reintegration point, capture
/// back (as a delta when the session negotiated it).
///
/// A `NeedFull` error means the delta could not be applied (no baseline /
/// digest mismatch); the caller relays it so the phone re-sends in full.
pub fn execute_migration(
    migrator: &Migrator,
    p: &mut Process,
    bytes: &[u8],
    fuel: u64,
    stats: &mut CloneServeStats,
    session: &mut CloneSession,
) -> Result<Vec<u8>> {
    let capsule = Capsule::decode(bytes)?;
    let is_delta = capsule.is_delta();
    let (tid, _) = migrator.receive_capsule_at_clone(p, &capsule, session)?;
    let instrs0 = p.metrics.instrs;

    // Drive the migrant to its reintegration point. Nested CcStart
    // means "already at the clone — continue" (Property 3 guarantees
    // migration/reintegration alternate).
    loop {
        match run_thread(p, tid, &mut NoHooks, fuel)? {
            RunExit::ReintegrationPoint { .. } => break,
            RunExit::MigrationPoint { .. } => continue,
            RunExit::Completed(_) => {
                return Err(CloneCloudError::migration(
                    "offloaded thread completed without a reintegration point",
                ))
            }
            RunExit::OutOfFuel => {
                return Err(CloneCloudError::migration("clone execution out of fuel"))
            }
        }
    }
    stats.migrations += 1;
    if is_delta {
        stats.delta_migrations += 1;
    }
    stats.instrs_executed += p.metrics.instrs - instrs0;
    let (rcapsule, _, dropped) = migrator.return_capsule_from_clone(p, tid, session)?;
    stats.mapping_entries_dropped += dropped;
    Ok(rcapsule.encode())
}

/// Byte accounting for one migration round trip.
#[derive(Debug, Clone, Copy, Default)]
pub struct TransferBytes {
    pub up: u64,
    pub down: u64,
}

/// The phone-side node manager.
pub struct NodeManager<T: Transport> {
    transport: T,
    /// Cumulative bytes moved (metrics).
    pub total: TransferBytes,
    /// Set by [`NodeManager::negotiate`]: both peers speak delta.
    delta_negotiated: bool,
}

impl<T: Transport> NodeManager<T> {
    pub fn new(transport: T) -> NodeManager<T> {
        NodeManager {
            transport,
            total: TransferBytes::default(),
            delta_negotiated: false,
        }
    }

    /// Negotiate protocol capabilities. Returns whether delta capsules
    /// may flow on this channel; a peer that answers `Error` (pre-v3) is
    /// treated as full-capture-only rather than a failure.
    pub fn negotiate(&mut self) -> Result<bool> {
        self.transport.send(&Msg::Hello {
            proto: PROTO_VERSION,
            delta: true,
        })?;
        self.delta_negotiated = match self.transport.recv()?.0 {
            Msg::Hello { proto, delta } => super::protocol::delta_agreed(proto, delta),
            // A peer that answers Error instead of Hello doesn't do
            // capability negotiation; stay on full captures. (A peer so
            // old it can't even *decode* Hello drops the transport, which
            // surfaces as the recv error above — callers treat a failed
            // negotiation as fatal for the connection, as they should.)
            Msg::Error(_) => false,
            other => {
                return Err(CloneCloudError::Transport(format!(
                    "expected Hello, got {other:?}"
                )))
            }
        };
        Ok(self.delta_negotiated)
    }

    /// Whether [`NodeManager::negotiate`] agreed on delta capsules.
    pub fn delta_negotiated(&self) -> bool {
        self.delta_negotiated
    }

    /// Re-Hello the peer with `delta = false` (the driver's session
    /// cannot merge reverse deltas, so the clone must stop emitting
    /// them). Best effort: a transport failure here will resurface on
    /// the next real call anyway.
    pub fn renegotiate_off(&mut self) {
        if !self.delta_negotiated {
            return;
        }
        self.delta_negotiated = false;
        let sent = self.transport.send(&Msg::Hello {
            proto: PROTO_VERSION,
            delta: false,
        });
        if sent.is_ok() {
            let _ = self.transport.recv(); // consume the peer's Hello reply
        }
    }

    fn expect_ack(&mut self) -> Result<()> {
        match self.transport.recv()?.0 {
            Msg::Ack => Ok(()),
            Msg::Error(e) => Err(CloneCloudError::Transport(format!("clone error: {e}"))),
            other => Err(CloneCloudError::Transport(format!("expected Ack, got {other:?}"))),
        }
    }

    /// Provision the clone (Zygote boot + executable identity check).
    pub fn provision(
        &mut self,
        program: &Program,
        zygote_objects: usize,
        zygote_seed: u64,
    ) -> Result<()> {
        self.transport.send(&Msg::Provision {
            zygote_objects: zygote_objects as u32,
            zygote_seed,
            program_hash: program_hash(program),
        })?;
        self.expect_ack()
    }

    /// Synchronize the file system image; returns bytes moved.
    pub fn sync_fs(&mut self, fs: &SimFs) -> Result<u64> {
        let n = self.transport.send(&Msg::SyncFs(fs.synchronize()))?;
        self.expect_ack()?;
        Ok(n)
    }

    /// One migration round trip: ship the forward capture, block for the
    /// reverse capture. Returns (reverse packet bytes, byte accounting).
    pub fn migrate(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        let up = self.transport.send(&Msg::Migrate(forward))?;
        let (msg, down) = self.transport.recv()?;
        let bytes = match msg {
            Msg::Reintegrate(b) => b,
            Msg::NeedFull(reason) => {
                // Typed, recoverable: the driver re-captures in full.
                self.total.up += up;
                return Err(CloneCloudError::NeedFull(reason));
            }
            Msg::Error(e) => {
                return Err(CloneCloudError::Transport(format!("clone error: {e}")))
            }
            other => {
                return Err(CloneCloudError::Transport(format!(
                    "expected Reintegrate, got {other:?}"
                )))
            }
        };
        let t = TransferBytes { up, down };
        self.total.up += up;
        self.total.down += down;
        Ok((bytes, t))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.transport.send(&Msg::Shutdown)?;
        Ok(())
    }
}
