//! Node managers and transports (paper §4).
//!
//! Each node runs a manager shared by its applications: it maintains the
//! single transport channel to the peer, synchronizes the file system,
//! provisions clone processes, and moves captured threads. Network
//! *timing* is a model (`config::NetworkProfile`, the paper's measured
//! 3G/WiFi parameters) applied to the *real* byte counts the transports
//! report.
//!
//! Two server shapes share one execution core ([`execute_migration`]):
//! [`CloneServer`] dedicates a clone to a single phone, while the farm
//! gateways front the multi-tenant farm (`crate::farm`) — same wire
//! protocol, many phones. The gateway itself comes in two
//! interchangeable builds: [`gateway`] (thread-per-connection, the
//! ablation baseline) and [`gateway_async`] (nonblocking sharded
//! readiness loop for C10k-scale phone swarms).
//!
//! See `docs/WIRE.md` for the complete wire reference and
//! `docs/ARCHITECTURE.md` for how this layer fits the whole system.
#![warn(missing_docs)]

pub mod gateway;
pub mod gateway_async;
pub mod manager;
pub mod protocol;
pub mod transport;

pub use gateway::{serve_farm, serve_farm_session};
pub use gateway_async::{serve_farm_async, AsyncGatewayConfig, GatewayKind, GatewayStats};
pub use manager::{
    execute_migration, CloneServeStats, CloneServer, NodeManager, TransferBytes,
};
pub use protocol::{
    codec_agreed, codec_agreed_at, decode_sub_job, decode_sub_result, delta_agreed,
    delta_agreed_at, dict_agreed, drive_heartbeat, encode_sub_result, is_sub_job, open_frame,
    patch_frame_payload, program_hash, seal_frame, seal_frame_keep_head, trace_agreed, Codec,
    FrameDecoder, HeartbeatOutcome, Msg, SubJobFrame, CAP_CODEC_LZ, CAP_SCATTER,
    CAP_SESSION_DICT, CAP_TRACE_CTX, DICT_MIN_PROTO, MAX_FRAME_BYTES,
    MAX_PREVALIDATION_ALLOC, PROTO_VERSION, SUB_JOB_PAYLOAD_OFFSET, SUPPORTED_CAPS,
    TRACE_MIN_PROTO,
};
pub use transport::{InProcTransport, TcpEndpoint, TcpTransport, Transport};

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::interp::{run_thread, NoHooks, RunExit};
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::process::Process;
    use crate::appvm::zygote::build_template;
    use crate::config::CostParams;
    use crate::device::{DeviceSpec, Location};
    use crate::migration::Migrator;
    use crate::vfs::SimFs;

    /// Worker reads a file (from the SYNCHRONIZED fs — "native
    /// everywhere") at the clone and returns its byte sum.
    const PROG: &str = r#"
class FsWork app
  static out
  method main nargs=0 regs=4
    invoke r0 FsWork.work
    puts FsWork.out r0
    retv
  end
  method work nargs=0 regs=10
    ccstart 0
    const r0 0
    const r1 0
    const r2 64
    invoke r3 FsWork.read r0 r1 r2
    len r4 r3
    const r5 0
    const r6 0
  loop:
    ifge r5 r4 @done
    aget r7 r3 r5
    add r6 r6 r7
    const r8 1
    add r5 r5 r8
    goto @loop
  done:
    ccstop 0
    ret r6
  end
  method read nargs=3 regs=3 native=fs.read
end
"#;

    #[test]
    fn end_to_end_migration_over_tcp_with_fs_sync() {
        let program = Arc::new(assemble(PROG).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let main = program.entry().unwrap();

        let mut phone_fs = SimFs::new();
        phone_fs.add("data.bin", (0u8..64).collect());
        let expected_sum: i64 = (0u8..64).map(|b| b as i64).sum();

        // Clone node on its own thread (its own env, its own backend).
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let server_program = program.clone();
        let server = std::thread::spawn(move || {
            let t = ep.accept().unwrap();
            let srv = CloneServer::new(
                t,
                server_program,
                CostParams::default(),
                Box::new(NodeEnv::with_rust_compute),
            );
            srv.serve().unwrap()
        });

        // Phone side.
        let mut nm = NodeManager::new(TcpTransport::connect(&addr).unwrap());
        nm.provision(&program, 500, 42).unwrap();
        nm.sync_fs(&phone_fs).unwrap();

        let template = build_template(&program, 500, 42);
        let mut phone = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(phone_fs),
        );
        let tid = phone.spawn_thread(main, &[]).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
        assert!(matches!(exit, RunExit::MigrationPoint { .. }));

        let migrator = Migrator::new(CostParams::default());
        let (packet, _) = migrator.migrate_out(&mut phone, tid).unwrap();
        let (rbytes, transfer) = nm.migrate(packet.encode().unwrap()).unwrap();
        assert!(transfer.up > 0 && transfer.down > 0);

        let rpacket = crate::migration::CapturePacket::decode(&rbytes).unwrap();
        migrator.merge_back(&mut phone, tid, &rpacket).unwrap();
        let exit = run_thread(&mut phone, tid, &mut NoHooks, 1_000_000).unwrap();
        assert!(matches!(exit, RunExit::Completed(_)), "{exit:?}");
        assert_eq!(
            phone.statics[main.class.0 as usize][0].as_int(),
            Some(expected_sum),
            "clone read the synchronized file and the result merged home"
        );

        nm.shutdown().unwrap();
        let stats = server.join().unwrap();
        assert_eq!(stats.migrations, 1);
        assert!(stats.instrs_executed > 64);
    }

    /// Wire-path delta session: Hello negotiation, then a multi-round
    /// offload where every repeat roundtrip rides a delta capsule over
    /// the Msg protocol, with the correct merged result.
    #[test]
    fn wire_delta_session_end_to_end() {
        use crate::config::NetworkProfile;
        use crate::exec::{delta_workload_expected, delta_workload_src, run_distributed_session};
        use crate::migration::MobileSession;

        const ROUNDS: i64 = 6;
        let program = Arc::new(assemble(&delta_workload_src(ROUNDS, 512)).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let main = program.entry().unwrap();

        let (phone_t, clone_t) = InProcTransport::pair();
        let srv_prog = program.clone();
        let server = std::thread::spawn(move || {
            let srv = CloneServer::new(
                clone_t,
                srv_prog,
                CostParams::default(),
                Box::new(NodeEnv::with_rust_compute),
            );
            srv.serve().unwrap()
        });

        let mut nm = NodeManager::new(phone_t);
        let delta = nm.negotiate().unwrap();
        assert!(delta);
        nm.provision(&program, 200, 5).unwrap();

        let template = build_template(&program, 200, 5);
        let mut phone = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(SimFs::new()),
        );
        let mut session = MobileSession::new(delta);
        let out = run_distributed_session(
            &mut phone,
            &mut nm,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
        )
        .unwrap();
        assert_eq!(out.migrations as i64, ROUNDS);
        assert_eq!(out.delta_roundtrips as i64, ROUNDS - 1, "repeat trips rode deltas");
        assert_eq!(out.delta_fallbacks, 0);
        assert_eq!(
            phone.statics[main.class.0 as usize][1].as_int(),
            Some(delta_workload_expected(ROUNDS))
        );

        nm.shutdown().unwrap();
        let stats = server.join().unwrap();
        assert_eq!(stats.migrations as i64, ROUNDS);
        assert_eq!(stats.delta_migrations as i64, ROUNDS - 1);
        assert_eq!(stats.delta_rejects, 0);
    }

    /// Wire path with the negotiated codec: frames ride compressed
    /// (wire < raw), results stay bit-identical, and a digest heartbeat
    /// round-trips as `Ack` while the baselines agree.
    #[test]
    fn wire_compressed_session_and_heartbeat() {
        use crate::config::NetworkProfile;
        use crate::exec::{delta_workload_expected, delta_workload_src, run_distributed_session};
        use crate::migration::MobileSession;

        const ROUNDS: i64 = 5;
        let program = Arc::new(assemble(&delta_workload_src(ROUNDS, 2_048)).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let main = program.entry().unwrap();

        let (phone_t, clone_t) = InProcTransport::pair();
        let srv_prog = program.clone();
        let server = std::thread::spawn(move || {
            let srv = CloneServer::new(
                clone_t,
                srv_prog,
                CostParams::default(),
                Box::new(NodeEnv::with_rust_compute),
            );
            srv.serve().unwrap()
        });

        let mut nm = NodeManager::new(phone_t);
        let delta = nm.negotiate().unwrap();
        assert!(delta);
        assert_eq!(nm.negotiated_codec(), Codec::Lz, "same-build peers talk LZ");
        assert_eq!(nm.negotiated_proto(), PROTO_VERSION);
        nm.provision(&program, 200, 5).unwrap();

        let template = build_template(&program, 200, 5);
        let mut phone = Process::fork_from_zygote(
            program.clone(),
            &template,
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(SimFs::new()),
        );
        let mut session = MobileSession::new(delta);
        let out = run_distributed_session(
            &mut phone,
            &mut nm,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
        )
        .unwrap();
        assert_eq!(out.migrations as i64, ROUNDS);
        assert_eq!(out.delta_fallbacks, 0);
        assert!(
            out.transfer.up < out.raw_up && out.transfer.down < out.raw_down,
            "sealed frames shrank the wire: {}/{} up, {}/{} down",
            out.transfer.up,
            out.raw_up,
            out.transfer.down,
            out.raw_down
        );
        assert_eq!(
            phone.statics[main.class.0 as usize][1].as_int(),
            Some(delta_workload_expected(ROUNDS))
        );

        // Digest heartbeat: both baselines describe the same state.
        assert_eq!(
            nm.heartbeat(&mut session).unwrap(),
            super::HeartbeatOutcome::Coherent
        );

        nm.shutdown().unwrap();
        let stats = server.join().unwrap();
        assert_eq!(stats.migrations as i64, ROUNDS);
        assert_eq!(stats.heartbeats, 1);
        assert_eq!(stats.heartbeat_divergent, 0);
    }

    /// Hello/Hello negotiation arms delta capsules on both ends.
    #[test]
    fn hello_negotiates_delta() {
        let program = Arc::new(assemble(PROG).unwrap());
        let (phone_t, clone_t) = InProcTransport::pair();
        let srv_prog = program;
        let server = std::thread::spawn(move || {
            let srv = CloneServer::new(
                clone_t,
                srv_prog,
                CostParams::default(),
                Box::new(NodeEnv::with_rust_compute),
            );
            srv.serve().unwrap()
        });
        let mut nm = NodeManager::new(phone_t);
        assert!(!nm.delta_negotiated());
        assert!(nm.negotiate().unwrap(), "v3 peers agree on delta");
        assert!(nm.delta_negotiated());
        nm.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn provision_rejects_program_mismatch() {
        let program = Arc::new(assemble(PROG).unwrap());
        let other = Arc::new(
            assemble("class B app\n  method main nargs=0 regs=1\n    retv\n  end\nend\n").unwrap(),
        );
        let (phone_t, clone_t) = InProcTransport::pair();
        let srv_prog = other;
        let server = std::thread::spawn(move || {
            let srv = CloneServer::new(
                clone_t,
                srv_prog,
                CostParams::default(),
                Box::new(NodeEnv::with_rust_compute),
            );
            // Serve exits on transport loss after the test drops nm.
            let _ = srv.serve();
        });
        let mut nm = NodeManager::new(phone_t);
        let err = nm.provision(&program, 10, 1).unwrap_err().to_string();
        assert!(err.contains("hash mismatch"), "{err}");
        nm.shutdown().unwrap();
        server.join().unwrap();
    }
}
