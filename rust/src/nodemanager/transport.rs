//! Transports: framed message channels between node managers.
//!
//! Two implementations: a real loopback **TCP** transport (the clone runs
//! a listener; frames are 4-byte big-endian length + payload) and an
//! **in-process** transport over `mpsc` channels (same framing semantics,
//! zero syscalls) for tests and single-process benchmarks. Virtual
//! network *cost* is applied by the exec driver from the byte counts
//! these transports report — the wire moves at host speed.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use crate::error::{CloneCloudError, Result};

use super::protocol::Msg;

/// A bidirectional message transport.
pub trait Transport {
    /// Send a message; returns encoded byte count (frame payload).
    fn send(&mut self, msg: &Msg) -> Result<u64>;
    /// Block for the next message; returns it with its byte count.
    fn recv(&mut self) -> Result<(Msg, u64)>;
}

// ---------------------------------------------------------------- in-proc

/// One endpoint of an in-process duplex channel.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl InProcTransport {
    /// Create a connected pair (phone end, clone end).
    pub fn pair() -> (InProcTransport, InProcTransport) {
        let (atx, brx) = channel();
        let (btx, arx) = channel();
        (
            InProcTransport { tx: atx, rx: arx },
            InProcTransport { tx: btx, rx: brx },
        )
    }
}

impl Transport for InProcTransport {
    fn send(&mut self, msg: &Msg) -> Result<u64> {
        let bytes = msg.encode();
        let n = bytes.len() as u64;
        self.tx
            .send(bytes)
            .map_err(|_| CloneCloudError::Transport("peer hung up".into()))?;
        Ok(n)
    }

    fn recv(&mut self) -> Result<(Msg, u64)> {
        let bytes = self
            .rx
            .recv()
            .map_err(|_| CloneCloudError::Transport("peer hung up".into()))?;
        let n = bytes.len() as u64;
        Ok((Msg::decode(&bytes)?, n))
    }
}

// -------------------------------------------------------------------- tcp

/// Framed TCP transport (4-byte big-endian length prefix).
///
/// Peer EOF *between* frames is a clean close: `recv` reports it as a
/// `Msg::Shutdown` so servers tear sessions down without error noise.
/// EOF *inside* a frame (truncated length or body) is still an error.
/// An optional read timeout bounds how long `recv` blocks, so a hung
/// peer cannot wedge the caller forever; a timeout is fatal to the
/// transport (the frame stream may be mid-frame and desynchronized).
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| CloneCloudError::Transport(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(TcpTransport { stream })
    }

    pub fn from_stream(stream: TcpStream) -> TcpTransport {
        stream.set_nodelay(true).ok();
        TcpTransport { stream }
    }

    /// Bound how long `recv` may block (`None` = wait forever).
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream
            .set_read_timeout(timeout)
            .map_err(|e| CloneCloudError::Transport(format!("set_read_timeout: {e}")))
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<u64> {
        let bytes = msg.encode();
        let len = (bytes.len() as u32).to_be_bytes();
        self.stream
            .write_all(&len)
            .and_then(|_| self.stream.write_all(&bytes))
            .map_err(|e| CloneCloudError::Transport(format!("send: {e}")))?;
        Ok(bytes.len() as u64)
    }

    fn recv(&mut self) -> Result<(Msg, u64)> {
        let mut len = [0u8; 4];
        // A clean close lands exactly on a frame boundary: only an EOF
        // before the first prefix byte reads as Shutdown. EOF after a
        // partial prefix is a truncated frame and stays an error.
        let mut got = 0usize;
        while got < 4 {
            match self.stream.read(&mut len[got..]) {
                Ok(0) if got == 0 => return Ok((Msg::Shutdown, 0)),
                Ok(0) => {
                    return Err(CloneCloudError::Transport(format!(
                        "recv len: eof after {got} of 4 prefix bytes"
                    )))
                }
                Ok(n) => got += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    let what = if is_timeout(&e) { "recv timed out" } else { "recv len" };
                    return Err(CloneCloudError::Transport(format!("{what}: {e}")));
                }
            }
        }
        let n = u32::from_be_bytes(len) as usize;
        let mut buf = vec![0u8; n];
        self.stream.read_exact(&mut buf).map_err(|e| {
            let what = if is_timeout(&e) { "recv timed out mid-frame" } else { "recv body" };
            CloneCloudError::Transport(format!("{what}: {e}"))
        })?;
        Ok((Msg::decode(&buf)?, n as u64))
    }
}

/// A TCP listener yielding one transport per accepted connection.
pub struct TcpEndpoint {
    listener: TcpListener,
}

impl TcpEndpoint {
    /// Bind to an address; use port 0 for an ephemeral port.
    pub fn bind(addr: &str) -> Result<TcpEndpoint> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| CloneCloudError::Transport(format!("bind {addr}: {e}")))?;
        Ok(TcpEndpoint { listener })
    }

    pub fn local_addr(&self) -> Result<String> {
        Ok(self
            .listener
            .local_addr()
            .map_err(|e| CloneCloudError::Transport(e.to_string()))?
            .to_string())
    }

    pub fn accept(&self) -> Result<TcpTransport> {
        let (stream, _) = self
            .listener
            .accept()
            .map_err(|e| CloneCloudError::Transport(format!("accept: {e}")))?;
        Ok(TcpTransport::from_stream(stream))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip() {
        let (mut a, mut b) = InProcTransport::pair();
        a.send(&Msg::Migrate(vec![1, 2, 3])).unwrap();
        let (m, n) = b.recv().unwrap();
        assert_eq!(m, Msg::Migrate(vec![1, 2, 3]));
        assert!(n > 3);
        b.send(&Msg::Ack).unwrap();
        assert_eq!(a.recv().unwrap().0, Msg::Ack);
    }

    #[test]
    fn tcp_peer_eof_is_clean_shutdown() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let mut t = ep.accept().unwrap();
            // First frame arrives normally, then the peer closes.
            assert_eq!(t.recv().unwrap().0, Msg::Ack);
            let (msg, n) = t.recv().unwrap();
            assert_eq!(msg, Msg::Shutdown, "EOF between frames reads as Shutdown");
            assert_eq!(n, 0);
        });
        {
            let mut c = TcpTransport::connect(&addr).unwrap();
            c.send(&Msg::Ack).unwrap();
        } // dropped: connection closed
        server.join().unwrap();
    }

    #[test]
    fn tcp_read_timeout_unwedges_recv() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        // Client connects but never sends anything (a hung clone).
        let _hung = TcpTransport::connect(&addr).unwrap();
        let mut t = ep.accept().unwrap();
        t.set_read_timeout(Some(std::time::Duration::from_millis(50)))
            .unwrap();
        let t0 = std::time::Instant::now();
        let err = t.recv().unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }

    #[test]
    fn tcp_roundtrip_loopback() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let mut t = ep.accept().unwrap();
            let (m, _) = t.recv().unwrap();
            assert_eq!(m, Msg::Migrate(vec![7; 100_000]), "large frame");
            t.send(&Msg::Ack).unwrap();
        });
        let mut c = TcpTransport::connect(&addr).unwrap();
        let sent = c.send(&Msg::Migrate(vec![7; 100_000])).unwrap();
        assert!(sent > 100_000);
        assert_eq!(c.recv().unwrap().0, Msg::Ack);
        server.join().unwrap();
    }
}
