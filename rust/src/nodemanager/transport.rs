//! Transports: framed message channels between node managers.
//!
//! Two implementations: a real loopback **TCP** transport (the clone runs
//! a listener; frames are 4-byte big-endian length + payload) and an
//! **in-process** transport over `mpsc` channels (same framing semantics,
//! zero syscalls) for tests and single-process benchmarks. Virtual
//! network *cost* is applied by the exec driver from the byte counts
//! these transports report — the wire moves at host speed.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use crate::error::{CloneCloudError, Result};

use super::protocol::{FrameDecoder, Msg};

/// A bidirectional message transport.
pub trait Transport {
    /// Send a message; returns encoded byte count (frame payload).
    fn send(&mut self, msg: &Msg) -> Result<u64>;
    /// Block for the next message; returns it with its byte count.
    fn recv(&mut self) -> Result<(Msg, u64)>;
}

// ---------------------------------------------------------------- in-proc

/// One endpoint of an in-process duplex channel.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl InProcTransport {
    /// Create a connected pair (phone end, clone end).
    pub fn pair() -> (InProcTransport, InProcTransport) {
        let (atx, brx) = channel();
        let (btx, arx) = channel();
        (
            InProcTransport { tx: atx, rx: arx },
            InProcTransport { tx: btx, rx: brx },
        )
    }
}

impl Transport for InProcTransport {
    fn send(&mut self, msg: &Msg) -> Result<u64> {
        let bytes = msg.encode()?;
        let n = bytes.len() as u64;
        self.tx
            .send(bytes)
            .map_err(|_| CloneCloudError::Transport("peer hung up".into()))?;
        Ok(n)
    }

    fn recv(&mut self) -> Result<(Msg, u64)> {
        let bytes = self
            .rx
            .recv()
            .map_err(|_| CloneCloudError::Transport("peer hung up".into()))?;
        let n = bytes.len() as u64;
        Ok((Msg::decode(&bytes)?, n))
    }
}

// -------------------------------------------------------------------- tcp

/// Framed TCP transport (4-byte big-endian length prefix), driven by
/// the same incremental [`FrameDecoder`] the async gateway uses.
///
/// Peer EOF *between* frames is a clean close: `recv` reports it as a
/// `Msg::Shutdown` so servers tear sessions down without error noise.
/// EOF *inside* a frame (truncated length or body) is still an error.
/// An optional read timeout bounds how long `recv` blocks, so a hung
/// peer cannot wedge the caller forever. Timeouts distinguish *where*
/// the stream stood: at a frame boundary an idle timeout is fatal (the
/// peer owed us nothing and the caller chose not to wait), but
/// **mid-frame a timeout only kills the transport when the peer made no
/// progress at all across a full timeout window** — a slow phone
/// dribbling a large capsule over a slow uplink keeps its session
/// instead of being silently retired mid-capsule.
pub struct TcpTransport {
    stream: TcpStream,
    decoder: FrameDecoder,
}

impl TcpTransport {
    /// Connect to a listening gateway/clone at `addr`.
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| CloneCloudError::Transport(format!("connect {addr}: {e}")))?;
        Ok(TcpTransport::from_stream(stream))
    }

    /// Wrap an accepted stream (sets TCP_NODELAY; frames are small).
    pub fn from_stream(stream: TcpStream) -> TcpTransport {
        stream.set_nodelay(true).ok();
        TcpTransport {
            stream,
            decoder: FrameDecoder::new(),
        }
    }

    /// Bound how long `recv` may block (`None` = wait forever).
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream
            .set_read_timeout(timeout)
            .map_err(|e| CloneCloudError::Transport(format!("set_read_timeout: {e}")))
    }
}

pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<u64> {
        let bytes = msg.encode()?;
        let len = (bytes.len() as u32).to_be_bytes();
        self.stream
            .write_all(&len)
            .and_then(|_| self.stream.write_all(&bytes))
            .map_err(|e| CloneCloudError::Transport(format!("send: {e}")))?;
        Ok(bytes.len() as u64)
    }

    fn recv(&mut self) -> Result<(Msg, u64)> {
        // A frame may already be fully buffered from an earlier read
        // that straddled a boundary.
        if let Some(frame) = self.decoder.next_frame()? {
            let n = frame.len() as u64;
            return Ok((Msg::decode(&frame)?, n));
        }
        let mut scratch = [0u8; 64 * 1024];
        // One timeout window with zero bytes of progress while
        // mid-frame means the peer stalled, not that it is slow.
        let mut progressed_since_timeout = false;
        loop {
            match self.stream.read(&mut scratch) {
                // A clean close lands exactly on a frame boundary: only
                // an EOF with nothing buffered reads as Shutdown. EOF
                // after a partial prefix/body is a truncated frame.
                Ok(0) if !self.decoder.mid_frame() => return Ok((Msg::Shutdown, 0)),
                Ok(0) => {
                    return Err(CloneCloudError::Transport(format!(
                        "recv: eof mid-frame with {} bytes buffered",
                        self.decoder.buffered()
                    )))
                }
                Ok(n) => {
                    progressed_since_timeout = true;
                    self.decoder.feed(&scratch[..n]);
                    if let Some(frame) = self.decoder.next_frame()? {
                        let n = frame.len() as u64;
                        return Ok((Msg::decode(&frame)?, n));
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if is_timeout(&e) => {
                    if !self.decoder.mid_frame() {
                        // Idle at a frame boundary: the bounded wait the
                        // caller asked for. Fatal, but clean.
                        return Err(CloneCloudError::Transport(format!(
                            "recv timed out: {e}"
                        )));
                    }
                    if progressed_since_timeout {
                        // Mid-frame but still moving: a slow peer, not a
                        // dead one. Grant another window.
                        progressed_since_timeout = false;
                        continue;
                    }
                    return Err(CloneCloudError::Transport(format!(
                        "recv: peer stalled mid-frame ({} bytes buffered): {e}",
                        self.decoder.buffered()
                    )));
                }
                Err(e) => {
                    return Err(CloneCloudError::Transport(format!("recv: {e}")));
                }
            }
        }
    }
}

/// A TCP listener yielding one transport per accepted connection.
pub struct TcpEndpoint {
    listener: TcpListener,
}

impl TcpEndpoint {
    /// Bind to an address; use port 0 for an ephemeral port.
    pub fn bind(addr: &str) -> Result<TcpEndpoint> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| CloneCloudError::Transport(format!("bind {addr}: {e}")))?;
        Ok(TcpEndpoint { listener })
    }

    /// The bound address as `ip:port` (resolves ephemeral port 0).
    pub fn local_addr(&self) -> Result<String> {
        Ok(self
            .listener
            .local_addr()
            .map_err(|e| CloneCloudError::Transport(e.to_string()))?
            .to_string())
    }

    /// Block for the next connection, wrapped as a framed transport.
    pub fn accept(&self) -> Result<TcpTransport> {
        let (stream, _) = self
            .listener
            .accept()
            .map_err(|e| CloneCloudError::Transport(format!("accept: {e}")))?;
        Ok(TcpTransport::from_stream(stream))
    }

    /// Switch the listener between blocking and nonblocking accepts
    /// (the async gateway polls; the blocking gateway waits).
    pub fn set_nonblocking(&self, on: bool) -> Result<()> {
        self.listener
            .set_nonblocking(on)
            .map_err(|e| CloneCloudError::Transport(format!("set_nonblocking: {e}")))
    }

    /// Nonblocking accept: `Ok(Some)` on a new connection, `Ok(None)`
    /// when none is pending. Only meaningful after
    /// [`TcpEndpoint::set_nonblocking`]`(true)`.
    pub fn poll_accept(&self) -> Result<Option<TcpStream>> {
        match self.listener.accept() {
            Ok((stream, _)) => Ok(Some(stream)),
            Err(e) if is_timeout(&e) => Ok(None),
            Err(e) if e.kind() == ErrorKind::Interrupted => Ok(None),
            Err(e) => Err(CloneCloudError::Transport(format!("accept: {e}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip() {
        let (mut a, mut b) = InProcTransport::pair();
        a.send(&Msg::Migrate(vec![1, 2, 3])).unwrap();
        let (m, n) = b.recv().unwrap();
        assert_eq!(m, Msg::Migrate(vec![1, 2, 3]));
        assert!(n > 3);
        b.send(&Msg::Ack).unwrap();
        assert_eq!(a.recv().unwrap().0, Msg::Ack);
    }

    #[test]
    fn tcp_peer_eof_is_clean_shutdown() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let mut t = ep.accept().unwrap();
            // First frame arrives normally, then the peer closes.
            assert_eq!(t.recv().unwrap().0, Msg::Ack);
            let (msg, n) = t.recv().unwrap();
            assert_eq!(msg, Msg::Shutdown, "EOF between frames reads as Shutdown");
            assert_eq!(n, 0);
        });
        {
            let mut c = TcpTransport::connect(&addr).unwrap();
            c.send(&Msg::Ack).unwrap();
        } // dropped: connection closed
        server.join().unwrap();
    }

    #[test]
    fn tcp_read_timeout_unwedges_recv() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        // Client connects but never sends anything (a hung clone).
        let _hung = TcpTransport::connect(&addr).unwrap();
        let mut t = ep.accept().unwrap();
        t.set_read_timeout(Some(std::time::Duration::from_millis(50)))
            .unwrap();
        let t0 = std::time::Instant::now();
        let err = t.recv().unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }

    /// A slow phone dribbling one frame across many timeout windows is
    /// NOT retired: every window sees progress, so `recv` keeps
    /// granting another. (This was the PR 8 bugfix — a mid-frame
    /// timeout used to kill the session like a hard error.)
    #[test]
    fn tcp_slow_dribble_mid_frame_survives_timeouts() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        // The server only starts its bounded recv once the first bytes
        // are already on the wire, so the *idle* timeout path cannot
        // race the client's first write.
        let (started_tx, started_rx) = channel();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_nodelay(true).ok();
            let msg = Msg::Migrate(vec![42; 64]);
            let payload = msg.encode().unwrap();
            let mut wire = (payload.len() as u32).to_be_bytes().to_vec();
            wire.extend_from_slice(&payload);
            let mut chunks = wire.chunks(5);
            s.write_all(chunks.next().unwrap()).unwrap();
            s.flush().ok();
            started_tx.send(()).unwrap();
            // Each remaining chunk is separated by more than the read
            // timeout: every window still sees progress.
            for chunk in chunks {
                std::thread::sleep(Duration::from_millis(30));
                s.write_all(chunk).unwrap();
                s.flush().ok();
            }
            s
        });
        let mut t = ep.accept().unwrap();
        t.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        started_rx.recv().unwrap();
        let (m, _) = t.recv().unwrap();
        assert_eq!(m, Msg::Migrate(vec![42; 64]));
        drop(client.join().unwrap());
    }

    /// A peer that goes silent *mid-frame* gets the distinct stall
    /// error — not the clean-Shutdown EOF path, not the idle-timeout
    /// message.
    #[test]
    fn tcp_stall_mid_frame_is_a_distinct_error() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        // Claim an 80-byte frame, deliver 3 bytes, then go silent.
        s.write_all(&80u32.to_be_bytes()).unwrap();
        s.write_all(&[1, 2, 3]).unwrap();
        s.flush().ok();
        let mut t = ep.accept().unwrap();
        t.set_read_timeout(Some(Duration::from_millis(40))).unwrap();
        let err = t.recv().unwrap_err().to_string();
        assert!(err.contains("stalled mid-frame"), "{err}");
        drop(s);
    }

    /// EOF mid-frame (peer died between prefix and body) stays a hard
    /// error, never a clean Shutdown.
    #[test]
    fn tcp_eof_mid_frame_is_an_error() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(&16u32.to_be_bytes()).unwrap();
            s.write_all(&[9; 4]).unwrap();
            s.flush().ok();
        } // dropped: half a frame on the wire, then EOF
        let mut t = ep.accept().unwrap();
        let err = t.recv().unwrap_err().to_string();
        assert!(err.contains("eof mid-frame"), "{err}");
    }

    /// Two frames arriving in one burst both come out of consecutive
    /// `recv` calls (the decoder buffers across boundaries).
    #[test]
    fn tcp_coalesced_frames_both_arrive() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut burst = Vec::new();
        for m in [Msg::Ack, Msg::NeedFull("x".into())] {
            let p = m.encode().unwrap();
            burst.extend_from_slice(&(p.len() as u32).to_be_bytes());
            burst.extend_from_slice(&p);
        }
        s.write_all(&burst).unwrap();
        s.flush().ok();
        let mut t = ep.accept().unwrap();
        assert_eq!(t.recv().unwrap().0, Msg::Ack);
        assert_eq!(t.recv().unwrap().0, Msg::NeedFull("x".into()));
        drop(s);
    }

    #[test]
    fn tcp_roundtrip_loopback() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let mut t = ep.accept().unwrap();
            let (m, _) = t.recv().unwrap();
            assert_eq!(m, Msg::Migrate(vec![7; 100_000]), "large frame");
            t.send(&Msg::Ack).unwrap();
        });
        let mut c = TcpTransport::connect(&addr).unwrap();
        let sent = c.send(&Msg::Migrate(vec![7; 100_000])).unwrap();
        assert!(sent > 100_000);
        assert_eq!(c.recv().unwrap().0, Msg::Ack);
        server.join().unwrap();
    }
}
