//! CloneCloud: boosting mobile device applications through cloud clone
//! execution — a full-system reproduction of Chun et al. (2010).
//!
//! Layer map (DESIGN.md):
//! * [`appvm`] — DroidVM, the Dalvik-like application VM substrate.
//!   Two execution tiers share one op-semantics core (`appvm::ops`):
//!   the switch-dispatch interpreter (tier 0, the ablation baseline)
//!   and the profile-guided **direct-threaded tier**
//!   ([`appvm::tier1`]) — hot offloaded methods are translated once
//!   into a pre-decoded superinstruction form, cached per method, and
//!   run bit-identically (same results, virtual-clock bits, epochs and
//!   error strings; enforced by `tests/exec_parity.rs`). Selected per
//!   clone via `config.exec_tier`; the phone always interprets.
//! * [`partitioner`] — static analysis + dynamic profiling + ILP solver
//!   + bytecode rewriter (paper §3). The rewriter emits either the
//!   classic one-partition binary or a *conditional* binary carrying
//!   every candidate `CcStart`; the partition DB stores per-span
//!   local/clone prices next to each entry.
//! * [`migration`] — thread suspend/capture/resume/merge with the
//!   MID/CID object-mapping table and Zygote-diff optimization (§4),
//!   plus epoch-based **delta migration**: per-session baseline caches
//!   ship only the mutated working set — heap objects *and* statics —
//!   on repeat offloads, with a digest-guarded full-capture fallback
//!   (`NeedFull`) and periodic **slot GC** (tombstone threads +
//!   orphaned object graphs reclaimed without evicting baselines). At
//!   Zygote scale, **per-page epochs** (`appvm::heap`, 64 ids/page) let
//!   the delta capture scan only dirty pages instead of traversing the
//!   reachable heap — deletions ride on mobile-side GC, and the
//!   canonical digest stays the safety net for any missed stamp.
//! * [`nodemanager`] — transport, wire protocol (v4: `Hello` capability
//!   bitmap — unknown bits ignored, never rejected — delta `NeedFull`
//!   frames, digest `Heartbeat` probes), negotiated frame compression
//!   (`util::compress`, LZ77/RLE, incompressible frames ride raw) and
//!   the **session string dictionary** (`CAP_SESSION_DICT`: capsules
//!   after the first ship only dictionary additions + indices; digest
//!   mismatch degrades to a NeedFull re-seed, never corruption), clone
//!   provisioning: the 1:1 `CloneServer` and the serve-many farm
//!   gateways — blocking thread-per-connection (the ablation) and the
//!   async sharded readiness loop (`gateway_async`, C10k front door).
//! * [`farm`] — the multi-tenant clone farm (beyond the paper): warm
//!   pool, placement policies, admission control, phone sessions
//!   multiplexed over clone workers; affinity-pinned slots retain the
//!   delta baseline across a phone's repeat migrations, answer digest
//!   heartbeats, and GC themselves on a configurable cadence.
//! * [`runtime`] — PJRT loader executing the AOT HLO artifacts built by
//!   `python/compile/aot.py` (L1 Pallas kernels + L2 JAX graphs).
//! * [`apps`] — the paper's three evaluation applications.
//! * [`exec`] — monolithic and distributed execution drivers, plus the
//!   **runtime partition policy** (`exec::policy`): a per-phone
//!   `PolicyEngine` re-decides migrate-vs-local at every `CcStart` from
//!   EWMA link estimates fed only by measured transfers and digest
//!   heartbeats, the session's capsule-size history, and the profiled
//!   span prices — decisions made *before* suspend/capture, scored
//!   after the fact (`offloads` / `local_fallbacks` /
//!   `mispredictions`), with forced-offload/forced-local ablations and
//!   dead-channel degrade-to-local.
//! * [`trace`] — the session flight recorder (§6's phase breakdown,
//!   live): a bounded ring of span/counter/instant/decision events
//!   stamped in both virtual and wall µs, an explicit `Tracer` handle
//!   threaded through driver, migration, protocol and farm (no
//!   globals), cross-endpoint causality via the `CAP_TRACE_CTX` wire
//!   context with clone events piggybacked on the reverse capsule, and
//!   Chrome trace-event export. Observe-only: tracing never changes
//!   execution results.
//! * [`baselines`] — comparison partitioners (§7 related work).
//!
//! Book-length companions in `docs/`: `docs/ARCHITECTURE.md` (layer
//! map, cross-PR invariants next to the code that binds them, one
//! request lifecycle end to end) and `docs/WIRE.md` (the complete wire
//! reference — framing, every message tag, negotiation, every
//! capability bit and frame magic).

pub mod appvm;
pub mod apps;
pub mod baselines;
pub mod clock;
pub mod config;
pub mod device;
pub mod error;
pub mod exec;
pub mod farm;
pub mod metrics;
pub mod migration;
pub mod nodemanager;
pub mod partitioner;
pub mod pipeline;
pub mod runtime;
pub mod trace;
pub mod util;
pub mod vfs;

pub use config::Config;
pub use error::{CloneCloudError, Result};
pub mod cli;
