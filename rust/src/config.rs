//! Configuration system: devices, networks, cost calibration.
//!
//! Every tunable in the reproduction lives here with paper-sourced
//! defaults, and can be overridden from a JSON file (`--config`) or
//! programmatically. The calibration constants map the simulator's
//! virtual-time charges onto the paper's measured scale (DESIGN.md §3);
//! Table 1's *shape* (who wins, crossovers, relative factors) is governed
//! by the ratios, not the absolute values.

use std::path::Path;

use crate::device::DeviceSpec;
use crate::error::{CloneCloudError, Result};
use crate::util::json::{self, Json};

/// Execution tier for offloaded spans on the clone side (see
/// `appvm::tier1`). The phone always interprets — tiering only pays
/// where spans are hot, and the paper's asymmetry lives on the clone.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecTierKind {
    /// Switch-dispatch interpreter only (ablation baseline).
    Interp,
    /// Profile-guided direct-threaded dispatch for hot methods.
    #[default]
    Tier1,
}

impl ExecTierKind {
    /// Parse a config string: "interp" | "tier1".
    pub fn parse(s: &str) -> Option<ExecTierKind> {
        match s {
            "interp" => Some(ExecTierKind::Interp),
            "tier1" => Some(ExecTierKind::Tier1),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ExecTierKind::Interp => "interp",
            ExecTierKind::Tier1 => "tier1",
        }
    }
}

/// Network link model between the phone and the cloud.
///
/// Direction convention is the phone's: `up_mbps` carries captures
/// phone -> clone, `down_mbps` carries them back.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkProfile {
    pub name: String,
    pub latency_ms: f64,
    pub down_mbps: f64,
    pub up_mbps: f64,
}

impl NetworkProfile {
    /// The paper's measured 3G link: 415 ms latency, 0.91 / 0.16 Mbps.
    pub fn threeg() -> NetworkProfile {
        NetworkProfile {
            name: "3g".into(),
            latency_ms: 415.0,
            down_mbps: 0.91,
            up_mbps: 0.16,
        }
    }

    /// The paper's measured WiFi link: 66 ms latency, 7.29 / 3.06 Mbps.
    pub fn wifi() -> NetworkProfile {
        NetworkProfile {
            name: "wifi".into(),
            latency_ms: 66.0,
            down_mbps: 7.29,
            up_mbps: 3.06,
        }
    }

    /// A degraded cellular link (EDGE-class) for the adaptive-policy
    /// ablations: 600 ms latency, 0.20 / 0.06 Mbps. On this link even a
    /// delta capsule usually costs more than running the span locally.
    pub fn edge() -> NetworkProfile {
        NetworkProfile {
            name: "edge".into(),
            latency_ms: 600.0,
            down_mbps: 0.20,
            up_mbps: 0.06,
        }
    }

    /// Lookup by name.
    pub fn by_name(name: &str) -> Option<NetworkProfile> {
        match name {
            "3g" | "threeg" => Some(Self::threeg()),
            "wifi" => Some(Self::wifi()),
            "edge" => Some(Self::edge()),
            _ => None,
        }
    }

    /// Virtual milliseconds to move `bytes` in the given direction,
    /// including one link latency.
    pub fn transfer_ms(&self, bytes: u64, up: bool) -> f64 {
        let mbps = if up { self.up_mbps } else { self.down_mbps };
        let bits = bytes as f64 * 8.0;
        self.latency_ms + bits / (mbps * 1e3)
    }
}

/// Cost calibration for the virtual-time model.
#[derive(Debug, Clone, PartialEq)]
pub struct CostParams {
    /// Baseline (clone-class) cost of one interpreted bytecode
    /// instruction, in µs. The phone multiplies by its cpu_factor.
    pub instr_us: f64,
    /// Baseline cost of one native compute work unit, per app kind, in
    /// µs (clone-class). Calibrated so the phone-monolithic column lands
    /// at the paper's order of magnitude (see DESIGN.md §3).
    pub scan_chunk_us: f64,
    pub face_detect_us: f64,
    pub categorize_us: f64,
    /// Thread suspend + resume machinery, per migration, µs baseline.
    pub suspend_resume_us: f64,
    /// Per-object capture (traverse + serialize) cost, µs baseline.
    pub capture_per_obj_us: f64,
    /// Per-object merge (patch references back into the running address
    /// space) cost, µs baseline. The paper observes merge dominating the
    /// WiFi migration cost (§6).
    pub merge_per_obj_us: f64,
    /// Per-byte merge cost, µs baseline (patching large array state).
    pub merge_per_byte_us: f64,
    /// Per-byte serialize/deserialize cost, µs baseline.
    pub per_byte_us: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            instr_us: 0.08,
            // One 4 KiB chunk against the 1000-signature library
            // (calibrated: 28 chunks/100 KB x 21x phone = ~5.7 s,
            // Table 1 row 1).
            scan_chunk_us: 9_700.0,
            // One image against the detector cascade (phone 1-image run
            // = ~22 s, Table 1 row 4).
            face_detect_us: 1_050_000.0,
            // One categorization panel visit (73 visits at depth 3 =
            // ~3.6 s on the phone, Table 1 row 7).
            categorize_us: 2_350.0,
            suspend_resume_us: 30_000.0,
            capture_per_obj_us: 2.0,
            merge_per_obj_us: 11.0,
            merge_per_byte_us: 0.55,
            per_byte_us: 0.012,
        }
    }
}

/// Clone-farm tunables (the `farm` config section; see `farm` module).
/// The policy is kept as a string here and validated by
/// `farm::PlacementPolicy::parse` when a farm is actually started.
#[derive(Debug, Clone, PartialEq)]
pub struct FarmParams {
    /// Clone worker threads (the pool size M).
    pub workers: usize,
    /// Pre-forked clone processes kept warm per worker.
    pub warm_per_worker: usize,
    /// Farm-wide bound on in-flight migrations (admission window).
    pub queue_depth: usize,
    /// Placement policy: "round-robin" | "least-loaded" | "affinity".
    pub policy: String,
    /// Gateway connection read timeout in ms (0 = no timeout).
    pub read_timeout_ms: u64,
    /// Collect a clone slot's garbage (tombstone threads + orphaned
    /// object graphs) every this many roundtrips (0 = never).
    pub slot_gc_interval: u64,
    /// Serve-path shape: "async" (sharded nonblocking readiness loop,
    /// the default) | "blocking" (thread-per-connection, the ablation).
    /// Validated by `nodemanager::GatewayKind::parse` at serve time.
    pub gateway: String,
    /// Shard threads for the async gateway (each owns a private
    /// connection table; ignored by the blocking gateway).
    pub gateway_shards: usize,
    /// Bounded accept→shard handoff queue depth for the async gateway
    /// (a full queue backpressures the acceptor).
    pub shard_queue_depth: usize,
}

impl Default for FarmParams {
    fn default() -> Self {
        FarmParams {
            workers: 4,
            warm_per_worker: 2,
            queue_depth: 64,
            policy: "affinity".into(),
            read_timeout_ms: 0,
            slot_gc_interval: 8,
            gateway: "async".into(),
            gateway_shards: 4,
            shard_queue_depth: 64,
        }
    }
}

/// Capture-path tunables (the `capture` config section; see
/// `migration::capture`).
#[derive(Debug, Clone, PartialEq)]
pub struct CaptureParams {
    /// Delta captures use the page-epoch dirty scan (O(dirty pages))
    /// instead of the per-object baseline traversal. Off = the PR 4
    /// shape, kept for ablation.
    pub paged: bool,
    /// Run a mobile-side heap GC every this many delta captures
    /// (0 = never). On the paged path GC is what turns unreachable
    /// baseline members into the capsule's `deleted` list.
    pub mobile_gc_interval: u64,
    /// Also trigger the mobile GC once the heap has grown by this many
    /// objects since the last collection (0 = count-based cadence
    /// only). A fast-allocating trace collects on growth, not on the
    /// fixed capture count — garbage stops riding delta capsules just
    /// when they would bloat most.
    pub mobile_gc_growth_objects: u64,
}

impl Default for CaptureParams {
    fn default() -> Self {
        CaptureParams {
            paged: true,
            mobile_gc_interval: 8,
            mobile_gc_growth_objects: 0,
        }
    }
}

/// Flight-recorder tunables (the `trace` config section; see `trace`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceParams {
    /// Record phase spans/counters/decisions into the session ring.
    /// Off = every tracer entry point is a no-op (the zero-cost path).
    pub enabled: bool,
    /// Bounded ring capacity, in events; the oldest events are dropped
    /// (and counted) once the ring is full.
    pub ring_capacity: usize,
    /// Ask the clone to piggyback its phase events on reverse capsules
    /// (`FLAG_WANT_CLONE_EVENTS` in the wire context) so one merged
    /// timeline covers both endpoints.
    pub ship_clone_events: bool,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            enabled: false,
            ring_capacity: 4096,
            ship_clone_events: true,
        }
    }
}

/// Runtime partition-policy tunables (the `policy` config section; see
/// `exec::policy`). The `force` override is kept as a string here and
/// validated by `exec::policy::ForceMode::parse` when an engine is
/// actually built.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyParams {
    /// Network-estimator EWMA half-life, in observed transfers: after
    /// this many roundtrips an old rate estimate has half its weight.
    pub half_life_trips: f64,
    /// Hysteresis margin on migrate-vs-local flips (fraction): the
    /// losing side must win by this factor before the decision changes.
    pub hysteresis: f64,
    /// Force one offload probe after this many consecutive local
    /// decisions, so the estimator keeps feeding from real transfers
    /// instead of going stale (0 = never probe).
    pub probe_trips: u64,
    /// Decision override for ablation: "auto" | "offload" | "local".
    pub force: String,
    /// Degrade a failed offload roundtrip to local execution of the
    /// span (error surfaced in `DistOutcome`) instead of failing the
    /// whole run.
    pub degrade_to_local: bool,
    /// Race local execution against the offload when the decision is
    /// marginal — |predicted offload − profiled local| below this many
    /// virtual ms — committing whichever leg finishes first on the
    /// virtual clock. 0 disables speculation.
    pub speculation_margin_ms: f64,
}

impl Default for PolicyParams {
    fn default() -> Self {
        PolicyParams {
            half_life_trips: 2.0,
            hysteresis: 0.1,
            probe_trips: 4,
            force: "auto".into(),
            degrade_to_local: true,
            speculation_margin_ms: 0.0,
        }
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub phone: DeviceSpec,
    pub clone: DeviceSpec,
    pub costs: CostParams,
    /// Directory holding the AOT artifacts (`manifest.json` + HLO text).
    pub artifacts_dir: String,
    /// Zygote template size (objects). Android's Zygote warms ~40k
    /// system-heap objects (§4.3 of the paper).
    pub zygote_objects: usize,
    /// Seed for all workload generation.
    pub seed: u64,
    /// Delta migration: ship only the mutated working set on repeat
    /// migrations (epoch-based dirty tracking + per-session baseline
    /// caches). Off = full capture every roundtrip (the paper's original
    /// behavior; also the automatic fallback whenever a baseline is
    /// missing or incoherent).
    pub delta_migration: bool,
    /// Send a digest-only heartbeat once a delta session's baseline has
    /// idled this long (ms, 0 = never): a diverged clone answers
    /// `NeedFull` *before* a doomed delta is built and shipped.
    pub heartbeat_idle_ms: u64,
    /// Session string dictionary: capsules after the first ship only
    /// dictionary additions plus indices (negotiated via the Hello
    /// `CAP_SESSION_DICT` bit; off = per-capsule tables even when the
    /// peer offers it).
    pub session_dict: bool,
    /// Clone-side execution tier: "tier1" (profile-guided
    /// direct-threaded dispatch) or "interp" (switch-dispatch ablation
    /// baseline). Bit-identical results either way — only wall time
    /// differs (see `appvm::tier1`).
    pub exec_tier: ExecTierKind,
    /// Capture-path tunables (page-epoch scan, mobile GC cadence).
    pub capture: CaptureParams,
    /// Flight-recorder tunables (phase tracing; see `trace`).
    pub trace: TraceParams,
    /// Clone-farm parameters (multi-tenant serving).
    pub farm: FarmParams,
    /// Runtime partition-policy parameters (per-invocation
    /// migrate-vs-local decisions; see `exec::policy`).
    pub policy: PolicyParams,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            phone: DeviceSpec::phone_g1(),
            clone: DeviceSpec::clone_desktop(),
            costs: CostParams::default(),
            artifacts_dir: "artifacts".into(),
            zygote_objects: 40_000,
            seed: 0xC10E,
            delta_migration: true,
            heartbeat_idle_ms: 30_000,
            session_dict: true,
            exec_tier: ExecTierKind::default(),
            capture: CaptureParams::default(),
            trace: TraceParams::default(),
            farm: FarmParams::default(),
            policy: PolicyParams::default(),
        }
    }
}

impl Config {
    /// Load overrides from a JSON file on top of defaults.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let v = json::parse(&text)?;
        Self::from_json(&v)
    }

    /// Apply a JSON object over defaults. Unknown keys are rejected so
    /// typos don't silently fall back to defaults.
    pub fn from_json(v: &Json) -> Result<Config> {
        let mut cfg = Config::default();
        let obj = v
            .as_obj()
            .ok_or_else(|| CloneCloudError::Config("config must be an object".into()))?;
        for (key, val) in obj {
            match key.as_str() {
                "phone_cpu_factor" => {
                    cfg.phone.cpu_factor = val
                        .as_f64()
                        .ok_or_else(|| CloneCloudError::Config("phone_cpu_factor".into()))?
                }
                "clone_cpu_factor" => {
                    cfg.clone.cpu_factor = val
                        .as_f64()
                        .ok_or_else(|| CloneCloudError::Config("clone_cpu_factor".into()))?
                }
                "artifacts_dir" => {
                    cfg.artifacts_dir = val
                        .as_str()
                        .ok_or_else(|| CloneCloudError::Config("artifacts_dir".into()))?
                        .to_string()
                }
                "zygote_objects" => {
                    cfg.zygote_objects = val
                        .as_usize()
                        .ok_or_else(|| CloneCloudError::Config("zygote_objects".into()))?
                }
                "seed" => {
                    cfg.seed = val
                        .as_i64()
                        .ok_or_else(|| CloneCloudError::Config("seed".into()))?
                        as u64
                }
                "delta_migration" => {
                    cfg.delta_migration = val
                        .as_bool()
                        .ok_or_else(|| CloneCloudError::Config("delta_migration".into()))?
                }
                "heartbeat_idle_ms" => {
                    cfg.heartbeat_idle_ms = val
                        .as_usize()
                        .ok_or_else(|| CloneCloudError::Config("heartbeat_idle_ms".into()))?
                        as u64
                }
                "session_dict" => {
                    cfg.session_dict = val
                        .as_bool()
                        .ok_or_else(|| CloneCloudError::Config("session_dict".into()))?
                }
                "exec_tier" => {
                    let s = val
                        .as_str()
                        .ok_or_else(|| CloneCloudError::Config("exec_tier".into()))?;
                    cfg.exec_tier = ExecTierKind::parse(s).ok_or_else(|| {
                        CloneCloudError::Config(format!(
                            "exec_tier must be \"interp\" or \"tier1\", got '{s}'"
                        ))
                    })?
                }
                "capture" => {
                    let c = val
                        .as_obj()
                        .ok_or_else(|| CloneCloudError::Config("capture must be object".into()))?;
                    for (ck, cv) in c {
                        match ck.as_str() {
                            "paged" => {
                                cfg.capture.paged = cv.as_bool().ok_or_else(|| {
                                    CloneCloudError::Config("capture.paged".into())
                                })?
                            }
                            "mobile_gc_interval" => {
                                cfg.capture.mobile_gc_interval =
                                    cv.as_usize().ok_or_else(|| {
                                        CloneCloudError::Config(
                                            "capture.mobile_gc_interval".into(),
                                        )
                                    })? as u64
                            }
                            "mobile_gc_growth_objects" => {
                                cfg.capture.mobile_gc_growth_objects =
                                    cv.as_usize().ok_or_else(|| {
                                        CloneCloudError::Config(
                                            "capture.mobile_gc_growth_objects".into(),
                                        )
                                    })? as u64
                            }
                            other => {
                                return Err(CloneCloudError::Config(format!(
                                    "unknown capture key '{other}'"
                                )))
                            }
                        }
                    }
                }
                "trace" => {
                    let c = val
                        .as_obj()
                        .ok_or_else(|| CloneCloudError::Config("trace must be object".into()))?;
                    for (tk, tv) in c {
                        match tk.as_str() {
                            "enabled" => {
                                cfg.trace.enabled = tv.as_bool().ok_or_else(|| {
                                    CloneCloudError::Config("trace.enabled".into())
                                })?
                            }
                            "ring_capacity" => {
                                cfg.trace.ring_capacity = tv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("trace.ring_capacity".into())
                                })?
                            }
                            "ship_clone_events" => {
                                cfg.trace.ship_clone_events = tv.as_bool().ok_or_else(|| {
                                    CloneCloudError::Config("trace.ship_clone_events".into())
                                })?
                            }
                            other => {
                                return Err(CloneCloudError::Config(format!(
                                    "unknown trace key '{other}'"
                                )))
                            }
                        }
                    }
                }
                "costs" => {
                    let c = val
                        .as_obj()
                        .ok_or_else(|| CloneCloudError::Config("costs must be object".into()))?;
                    for (ck, cv) in c {
                        let x = cv
                            .as_f64()
                            .ok_or_else(|| CloneCloudError::Config(format!("costs.{ck}")))?;
                        match ck.as_str() {
                            "instr_us" => cfg.costs.instr_us = x,
                            "scan_chunk_us" => cfg.costs.scan_chunk_us = x,
                            "face_detect_us" => cfg.costs.face_detect_us = x,
                            "categorize_us" => cfg.costs.categorize_us = x,
                            "suspend_resume_us" => cfg.costs.suspend_resume_us = x,
                            "capture_per_obj_us" => cfg.costs.capture_per_obj_us = x,
                            "merge_per_obj_us" => cfg.costs.merge_per_obj_us = x,
                            "merge_per_byte_us" => cfg.costs.merge_per_byte_us = x,
                            "per_byte_us" => cfg.costs.per_byte_us = x,
                            other => {
                                return Err(CloneCloudError::Config(format!(
                                    "unknown costs key '{other}'"
                                )))
                            }
                        }
                    }
                }
                "farm" => {
                    let f = val
                        .as_obj()
                        .ok_or_else(|| CloneCloudError::Config("farm must be object".into()))?;
                    for (fk, fv) in f {
                        match fk.as_str() {
                            "workers" => {
                                cfg.farm.workers = fv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("farm.workers".into())
                                })?
                            }
                            "warm_per_worker" => {
                                cfg.farm.warm_per_worker = fv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("farm.warm_per_worker".into())
                                })?
                            }
                            "queue_depth" => {
                                cfg.farm.queue_depth = fv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("farm.queue_depth".into())
                                })?
                            }
                            "policy" => {
                                cfg.farm.policy = fv
                                    .as_str()
                                    .ok_or_else(|| {
                                        CloneCloudError::Config("farm.policy".into())
                                    })?
                                    .to_string()
                            }
                            "read_timeout_ms" => {
                                cfg.farm.read_timeout_ms = fv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("farm.read_timeout_ms".into())
                                })?
                                    as u64
                            }
                            "slot_gc_interval" => {
                                cfg.farm.slot_gc_interval = fv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("farm.slot_gc_interval".into())
                                })?
                                    as u64
                            }
                            "gateway" => {
                                let g = fv
                                    .as_str()
                                    .ok_or_else(|| {
                                        CloneCloudError::Config("farm.gateway".into())
                                    })?
                                    .to_string();
                                if !matches!(g.as_str(), "async" | "blocking") {
                                    return Err(CloneCloudError::Config(format!(
                                        "farm.gateway must be \"async\" or \"blocking\", got '{g}'"
                                    )));
                                }
                                cfg.farm.gateway = g;
                            }
                            "gateway_shards" => {
                                cfg.farm.gateway_shards = fv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("farm.gateway_shards".into())
                                })?
                            }
                            "shard_queue_depth" => {
                                cfg.farm.shard_queue_depth = fv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("farm.shard_queue_depth".into())
                                })?
                            }
                            other => {
                                return Err(CloneCloudError::Config(format!(
                                    "unknown farm key '{other}'"
                                )))
                            }
                        }
                    }
                }
                "policy" => {
                    let p = val
                        .as_obj()
                        .ok_or_else(|| CloneCloudError::Config("policy must be object".into()))?;
                    for (pk, pv) in p {
                        match pk.as_str() {
                            "half_life_trips" => {
                                cfg.policy.half_life_trips = pv.as_f64().ok_or_else(|| {
                                    CloneCloudError::Config("policy.half_life_trips".into())
                                })?
                            }
                            "hysteresis" => {
                                cfg.policy.hysteresis = pv.as_f64().ok_or_else(|| {
                                    CloneCloudError::Config("policy.hysteresis".into())
                                })?
                            }
                            "probe_trips" => {
                                cfg.policy.probe_trips = pv.as_usize().ok_or_else(|| {
                                    CloneCloudError::Config("policy.probe_trips".into())
                                })?
                                    as u64
                            }
                            "force" => {
                                cfg.policy.force = pv
                                    .as_str()
                                    .ok_or_else(|| {
                                        CloneCloudError::Config("policy.force".into())
                                    })?
                                    .to_string()
                            }
                            "degrade_to_local" => {
                                cfg.policy.degrade_to_local = pv.as_bool().ok_or_else(|| {
                                    CloneCloudError::Config("policy.degrade_to_local".into())
                                })?
                            }
                            "speculation_margin_ms" => {
                                cfg.policy.speculation_margin_ms =
                                    pv.as_f64().ok_or_else(|| {
                                        CloneCloudError::Config(
                                            "policy.speculation_margin_ms".into(),
                                        )
                                    })?
                            }
                            other => {
                                return Err(CloneCloudError::Config(format!(
                                    "unknown policy key '{other}'"
                                )))
                            }
                        }
                    }
                }
                other => {
                    return Err(CloneCloudError::Config(format!(
                        "unknown config key '{other}'"
                    )))
                }
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_network_parameters() {
        let g = NetworkProfile::threeg();
        assert_eq!(g.latency_ms, 415.0);
        let w = NetworkProfile::wifi();
        assert_eq!(w.latency_ms, 66.0);
        assert!(w.up_mbps > g.up_mbps * 10.0);
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let w = NetworkProfile::wifi();
        let t1 = w.transfer_ms(100_000, true);
        let t2 = w.transfer_ms(200_000, true);
        assert!(t2 > t1);
        // 100 KB at 3.06 Mbps ~ 261 ms + 66 ms latency.
        assert!((t1 - (66.0 + 800_000.0 / 3060.0)).abs() < 1.0);
    }

    #[test]
    fn uplink_slower_than_downlink() {
        let g = NetworkProfile::threeg();
        assert!(g.transfer_ms(1 << 20, true) > g.transfer_ms(1 << 20, false));
    }

    #[test]
    fn config_from_json_overrides() {
        let v = json::parse(
            r#"{"phone_cpu_factor": 25.0, "costs": {"instr_us": 0.5}, "seed": 7}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&v).unwrap();
        assert_eq!(cfg.phone.cpu_factor, 25.0);
        assert_eq!(cfg.costs.instr_us, 0.5);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.clone.cpu_factor, 1.0, "untouched default");
    }

    #[test]
    fn delta_migration_knob() {
        assert!(Config::default().delta_migration, "delta on by default");
        let v = json::parse(r#"{"delta_migration": false}"#).unwrap();
        assert!(!Config::from_json(&v).unwrap().delta_migration);
        let bad = json::parse(r#"{"delta_migration": 3}"#).unwrap();
        assert!(Config::from_json(&bad).is_err(), "non-bool rejected");
    }

    #[test]
    fn session_dict_and_capture_knobs() {
        let d = Config::default();
        assert!(d.session_dict, "dictionary on by default");
        assert!(d.capture.paged, "paged captures on by default");
        assert_eq!(d.capture.mobile_gc_interval, 8);

        let v = json::parse(
            r#"{"session_dict": false,
                "capture": {"paged": false, "mobile_gc_interval": 0}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&v).unwrap();
        assert!(!cfg.session_dict);
        assert!(!cfg.capture.paged, "per-object ablation reachable");
        assert_eq!(cfg.capture.mobile_gc_interval, 0, "GC can be disabled");

        let bad = json::parse(r#"{"capture": {"pagde": true}}"#).unwrap();
        assert!(Config::from_json(&bad).is_err(), "typo'd capture key rejected");
        let bad2 = json::parse(r#"{"session_dict": 3}"#).unwrap();
        assert!(Config::from_json(&bad2).is_err(), "non-bool rejected");
    }

    #[test]
    fn gc_growth_trigger_knob() {
        assert_eq!(
            Config::default().capture.mobile_gc_growth_objects,
            0,
            "growth trigger off by default"
        );
        let v = json::parse(r#"{"capture": {"mobile_gc_growth_objects": 500}}"#).unwrap();
        assert_eq!(
            Config::from_json(&v).unwrap().capture.mobile_gc_growth_objects,
            500
        );
        let bad = json::parse(r#"{"capture": {"mobile_gc_growth_objects": "lots"}}"#).unwrap();
        assert!(Config::from_json(&bad).is_err(), "non-numeric rejected");
    }

    #[test]
    fn trace_section_overrides_and_validates() {
        let d = Config::default().trace;
        assert!(!d.enabled, "tracing off by default");
        assert_eq!(d.ring_capacity, 4096);
        assert!(d.ship_clone_events);

        let v = json::parse(
            r#"{"trace": {"enabled": true, "ring_capacity": 256,
                "ship_clone_events": false}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&v).unwrap();
        assert!(cfg.trace.enabled);
        assert_eq!(cfg.trace.ring_capacity, 256);
        assert!(!cfg.trace.ship_clone_events);

        let bad = json::parse(r#"{"trace": {"enbaled": true}}"#).unwrap();
        assert!(Config::from_json(&bad).is_err(), "typo'd trace key rejected");
        let bad2 = json::parse(r#"{"trace": {"ring_capacity": false}}"#).unwrap();
        assert!(Config::from_json(&bad2).is_err(), "non-numeric rejected");
    }

    #[test]
    fn heartbeat_idle_knob() {
        assert_eq!(Config::default().heartbeat_idle_ms, 30_000);
        let v = json::parse(r#"{"heartbeat_idle_ms": 0}"#).unwrap();
        assert_eq!(Config::from_json(&v).unwrap().heartbeat_idle_ms, 0);
        let bad = json::parse(r#"{"heartbeat_idle_ms": "soon"}"#).unwrap();
        assert!(Config::from_json(&bad).is_err(), "non-numeric rejected");
    }

    #[test]
    fn farm_section_overrides_and_validates() {
        let v = json::parse(
            r#"{"farm": {"workers": 8, "queue_depth": 16, "policy": "least-loaded", "slot_gc_interval": 0}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&v).unwrap();
        assert_eq!(cfg.farm.workers, 8);
        assert_eq!(cfg.farm.queue_depth, 16);
        assert_eq!(cfg.farm.policy, "least-loaded");
        assert_eq!(cfg.farm.slot_gc_interval, 0, "slot GC can be disabled");
        assert_eq!(cfg.farm.warm_per_worker, 2, "untouched default");
        assert_eq!(
            Config::default().farm.slot_gc_interval,
            8,
            "slot GC on by default"
        );

        let bad = json::parse(r#"{"farm": {"wrokers": 8}}"#).unwrap();
        assert!(Config::from_json(&bad).is_err(), "typo'd farm key rejected");
    }

    #[test]
    fn farm_gateway_knobs() {
        let d = Config::default().farm;
        assert_eq!(d.gateway, "async", "async serve path is the default");
        assert_eq!(d.gateway_shards, 4);
        assert_eq!(d.shard_queue_depth, 64);

        let v = json::parse(
            r#"{"farm": {"gateway": "blocking", "gateway_shards": 8, "shard_queue_depth": 16}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&v).unwrap();
        assert_eq!(cfg.farm.gateway, "blocking", "ablation stays selectable");
        assert_eq!(cfg.farm.gateway_shards, 8);
        assert_eq!(cfg.farm.shard_queue_depth, 16);

        let bad = json::parse(r#"{"farm": {"gateway": "epoll"}}"#).unwrap();
        let err = Config::from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("farm.gateway"), "{err}");
    }

    #[test]
    fn policy_section_overrides_and_validates() {
        let d = Config::default().policy;
        assert_eq!(d.half_life_trips, 2.0);
        assert_eq!(d.force, "auto");
        assert!(d.degrade_to_local);

        assert_eq!(d.speculation_margin_ms, 0.0, "speculation is opt-in");

        let v = json::parse(
            r#"{"policy": {"half_life_trips": 1.0, "hysteresis": 0.25,
                "probe_trips": 0, "force": "local", "degrade_to_local": false,
                "speculation_margin_ms": 40.0}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&v).unwrap();
        assert_eq!(cfg.policy.half_life_trips, 1.0);
        assert_eq!(cfg.policy.hysteresis, 0.25);
        assert_eq!(cfg.policy.probe_trips, 0, "probing can be disabled");
        assert_eq!(cfg.policy.force, "local");
        assert!(!cfg.policy.degrade_to_local);
        assert_eq!(cfg.policy.speculation_margin_ms, 40.0);

        let bad = json::parse(r#"{"policy": {"hysterisis": 0.2}}"#).unwrap();
        assert!(Config::from_json(&bad).is_err(), "typo'd policy key rejected");
    }

    #[test]
    fn exec_tier_knob() {
        assert_eq!(
            Config::default().exec_tier,
            ExecTierKind::Tier1,
            "tiered execution on by default"
        );
        let v = json::parse(r#"{"exec_tier": "interp"}"#).unwrap();
        assert_eq!(
            Config::from_json(&v).unwrap().exec_tier,
            ExecTierKind::Interp,
            "ablation baseline selectable"
        );
        assert_eq!(ExecTierKind::parse("tier1"), Some(ExecTierKind::Tier1));
        assert_eq!(ExecTierKind::Tier1.as_str(), "tier1");
        assert_eq!(ExecTierKind::Interp.as_str(), "interp");
        let bad = json::parse(r#"{"exec_tier": "tier2"}"#).unwrap();
        assert!(Config::from_json(&bad).is_err(), "unknown tier rejected");
        let bad2 = json::parse(r#"{"exec_tier": 1}"#).unwrap();
        assert!(Config::from_json(&bad2).is_err(), "non-string rejected");
    }

    #[test]
    fn edge_profile_is_strictly_worse_than_threeg() {
        let e = NetworkProfile::edge();
        let g = NetworkProfile::threeg();
        assert_eq!(NetworkProfile::by_name("edge"), Some(e.clone()));
        assert!(e.latency_ms > g.latency_ms && e.up_mbps < g.up_mbps);
        assert!(e.transfer_ms(10_000, true) > g.transfer_ms(10_000, true));
    }

    #[test]
    fn config_rejects_unknown_keys() {
        let v = json::parse(r#"{"phnoe_cpu_factor": 25.0}"#).unwrap();
        assert!(Config::from_json(&v).is_err());
        let v2 = json::parse(r#"{"costs": {"instr_usec": 1.0}}"#).unwrap();
        assert!(Config::from_json(&v2).is_err());
    }
}
