//! Error taxonomy for the CloneCloud stack.

use thiserror::Error;

/// All errors surfaced by the library.
#[derive(Debug, Error)]
pub enum CloneCloudError {
    /// Bytecode loading / assembling problems.
    #[error("program error: {0}")]
    Program(String),

    /// Bytecode verifier rejections.
    #[error("verifier error in {method}: {message}")]
    Verify { method: String, message: String },

    /// Runtime faults inside the application VM (null deref, bad index...).
    #[error("vm fault: {0}")]
    VmFault(String),

    /// Native method failures.
    #[error("native error in {name}: {message}")]
    Native { name: String, message: String },

    /// Migration capture/merge failures.
    #[error("migration error: {0}")]
    Migration(String),

    /// Wire-format decode failures.
    #[error("wire error: {0}")]
    Wire(String),

    /// Node-manager / transport failures.
    #[error("transport error: {0}")]
    Transport(String),

    /// Partitioner failures (analysis, profiling, solving).
    #[error("partitioner error: {0}")]
    Partitioner(String),

    /// ILP solver failures (infeasible, unbounded, iteration limit).
    #[error("solver error: {0}")]
    Solver(String),

    /// PJRT runtime failures.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Configuration problems.
    #[error("config error: {0}")]
    Config(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),
}

pub type Result<T> = std::result::Result<T, CloneCloudError>;

impl CloneCloudError {
    pub fn vm(msg: impl Into<String>) -> Self {
        CloneCloudError::VmFault(msg.into())
    }
    pub fn program(msg: impl Into<String>) -> Self {
        CloneCloudError::Program(msg.into())
    }
    pub fn migration(msg: impl Into<String>) -> Self {
        CloneCloudError::Migration(msg.into())
    }
    pub fn partitioner(msg: impl Into<String>) -> Self {
        CloneCloudError::Partitioner(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        CloneCloudError::Runtime(msg.into())
    }
}
