//! Error taxonomy for the CloneCloud stack.
//!
//! Hand-rolled `Display`/`Error` impls: the offline build environment has
//! no proc-macro crates (thiserror), so the derive is spelled out.

use std::fmt;

/// All errors surfaced by the library.
#[derive(Debug)]
pub enum CloneCloudError {
    /// Bytecode loading / assembling problems.
    Program(String),

    /// Bytecode verifier rejections.
    Verify { method: String, message: String },

    /// Runtime faults inside the application VM (null deref, bad index...).
    VmFault(String),

    /// Native method failures.
    Native { name: String, message: String },

    /// Migration capture/merge failures.
    Migration(String),

    /// A delta capsule was rejected because the receiver does not hold
    /// the negotiated baseline (first contact, recycled worker, digest
    /// mismatch). Recoverable: the sender re-captures in full.
    NeedFull(String),

    /// Two scatter shards returned overlapping dirty state, so their
    /// reverse capsules cannot be merged against the shared baseline.
    /// Detected before any mutation: the process and baseline are left
    /// untouched and the driver degrades to a single-clone offload.
    ScatterConflict(String),

    /// Wire-format decode failures.
    Wire(String),

    /// Node-manager / transport failures.
    Transport(String),

    /// Partitioner failures (analysis, profiling, solving).
    Partitioner(String),

    /// ILP solver failures (infeasible, unbounded, iteration limit).
    Solver(String),

    /// PJRT runtime failures.
    Runtime(String),

    /// Configuration problems.
    Config(String),

    Io(std::io::Error),

    Json(crate::util::json::JsonError),
}

impl fmt::Display for CloneCloudError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CloneCloudError::Program(m) => write!(f, "program error: {m}"),
            CloneCloudError::Verify { method, message } => {
                write!(f, "verifier error in {method}: {message}")
            }
            CloneCloudError::VmFault(m) => write!(f, "vm fault: {m}"),
            CloneCloudError::Native { name, message } => {
                write!(f, "native error in {name}: {message}")
            }
            CloneCloudError::Migration(m) => write!(f, "migration error: {m}"),
            CloneCloudError::NeedFull(m) => {
                write!(f, "delta rejected: {m} (resend a full capture)")
            }
            CloneCloudError::ScatterConflict(m) => {
                write!(f, "scatter conflict: {m} (degrade to single-clone)")
            }
            CloneCloudError::Wire(m) => write!(f, "wire error: {m}"),
            CloneCloudError::Transport(m) => write!(f, "transport error: {m}"),
            CloneCloudError::Partitioner(m) => write!(f, "partitioner error: {m}"),
            CloneCloudError::Solver(m) => write!(f, "solver error: {m}"),
            CloneCloudError::Runtime(m) => write!(f, "runtime error: {m}"),
            CloneCloudError::Config(m) => write!(f, "config error: {m}"),
            CloneCloudError::Io(e) => write!(f, "io error: {e}"),
            CloneCloudError::Json(e) => write!(f, "json error: {e}"),
        }
    }
}

impl std::error::Error for CloneCloudError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CloneCloudError::Io(e) => Some(e),
            CloneCloudError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CloneCloudError {
    fn from(e: std::io::Error) -> Self {
        CloneCloudError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for CloneCloudError {
    fn from(e: crate::util::json::JsonError) -> Self {
        CloneCloudError::Json(e)
    }
}

pub type Result<T> = std::result::Result<T, CloneCloudError>;

impl CloneCloudError {
    pub fn vm(msg: impl Into<String>) -> Self {
        CloneCloudError::VmFault(msg.into())
    }
    pub fn program(msg: impl Into<String>) -> Self {
        CloneCloudError::Program(msg.into())
    }
    pub fn migration(msg: impl Into<String>) -> Self {
        CloneCloudError::Migration(msg.into())
    }
    pub fn need_full(msg: impl Into<String>) -> Self {
        CloneCloudError::NeedFull(msg.into())
    }
    /// True when the error is the recoverable "resend a full capture"
    /// signal of the delta-migration path.
    pub fn is_need_full(&self) -> bool {
        matches!(self, CloneCloudError::NeedFull(_))
    }
    pub fn scatter_conflict(msg: impl Into<String>) -> Self {
        CloneCloudError::ScatterConflict(msg.into())
    }
    /// True when concurrent shard results touched overlapping state and
    /// the gather was (safely) refused before mutating anything.
    pub fn is_scatter_conflict(&self) -> bool {
        matches!(self, CloneCloudError::ScatterConflict(_))
    }
    pub fn partitioner(msg: impl Into<String>) -> Self {
        CloneCloudError::Partitioner(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        CloneCloudError::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_derive_format() {
        assert_eq!(
            CloneCloudError::Transport("peer hung up".into()).to_string(),
            "transport error: peer hung up"
        );
        assert_eq!(
            CloneCloudError::Verify {
                method: "A.main".into(),
                message: "bad reg".into()
            }
            .to_string(),
            "verifier error in A.main: bad reg"
        );
        assert_eq!(
            CloneCloudError::Native {
                name: "fs.read".into(),
                message: "no file".into()
            }
            .to_string(),
            "native error in fs.read: no file"
        );
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: CloneCloudError =
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
