//! Tier-1 execution engine: profile-guided direct-threaded dispatch.
//!
//! The clone exists to run the offloaded span faster than the phone
//! (paper §1 — up to 21.2x); this module is where that speed actually
//! comes from inside the reproduction, instead of only the
//! `device.scale_us` config multiplier. When a method crosses a hotness
//! threshold (activation count, or a long uninterrupted run inside one
//! method), its `Instr` sequence is translated **once** into a
//! pre-decoded direct-threaded form ([`Translation`]):
//!
//! - operand registers are resolved to plain indices with a single
//!   up-front `min_regs` bound, so segment execution indexes the
//!   register file directly instead of bounds-checking per operand;
//! - branch targets are pre-bound to translated-op indices (no pc → op
//!   re-decode on the back edge of a loop);
//! - the dominant adjacent patterns are fused into superinstructions
//!   (`Const`+`IntBin`, `IntBin`+`Goto`, `Const`+`IntBin`+`Goto`),
//!   eliminating dispatch between them;
//! - heavy instructions (invoke/return/allocation/statics stores/
//!   `CcStart`/`CcStop`) become [`TOp::Bail`] entries that fall back to
//!   the shared single-step [`super::ops::step_one`], so their
//!   semantics exist exactly once.
//!
//! Translations are cached per `MRef` in a bounded FIFO cache owned by
//! the engine (one engine per clone process / farm slot), invalidated
//! when the process's `Arc<Program>` identity changes — the engine holds
//! the `Arc`, so a pointer compare cannot alias a dropped program.
//!
//! # Bit-identity contract
//!
//! Tier 1 MUST be indistinguishable from the interpreter in everything
//! but wall time: same `Value` results, same per-instruction
//! `clock.charge_us` order (the clock and `cpu_us` are f64 accumulators
//! — batching charges would change the bits), same `Heap::get_mut`
//! write-barrier stamping, same fuel semantics (the instruction that
//! would exceed the budget is not executed and `frame.pc` points at
//! it), same error strings with `frame.pc` advanced past the faulting
//! instruction. Statically suspect methods (an operand register beyond
//! `nregs`, an invalid static slot, a branch target past the method
//! end) are left **untranslated** so their lazy, only-if-executed fault
//! behaviour stays with the cold path. `tests/exec_parity.rs` enforces
//! the contract over randomized programs and every example workload.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use super::bytecode::{eval_float, eval_int, CmpOp, FloatOp, Instr, IntOp, MRef};
use super::class::{MethodDef, Program};
use super::interp::{self, NoHooks, RunExit};
use super::ops;
use super::process::{Process, VmMetrics};
use super::thread::{Frame, ThreadStatus, VmThread};
use super::value::{ObjBody, ObjId, Value};
use crate::clock::VirtualClock;
use crate::config::{CostParams, ExecTierKind};
use crate::error::{CloneCloudError, Result};

/// Sentinel in `pc_to_top` for pcs inside a fused superinstruction.
const NO_TOP: u32 = u32::MAX;

/// One pre-decoded translated op. `src` is the pc of the first source
/// instruction, kept so exits and faults can restore the exact
/// interpreter pc. Branch ops carry both the pre-bound translated-op
/// index (`t_top`) and the original pc (`t_pc` — what `frame.pc` must
/// say if the segment exits right after the jump).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum TOp {
    Nop { src: u32 },
    ConstI { src: u32, d: u8, v: i64 },
    ConstF { src: u32, d: u8, v: f64 },
    Move { src: u32, d: u8, s: u8 },
    IntBin { src: u32, op: IntOp, d: u8, a: u8, b: u8 },
    FloatBin { src: u32, op: FloatOp, d: u8, a: u8, b: u8 },
    Cmp { src: u32, op: CmpOp, d: u8, a: u8, b: u8 },
    IfZ { src: u32, r: u8, t_top: u32, t_pc: u32 },
    IfNZ { src: u32, r: u8, t_top: u32, t_pc: u32 },
    IfCmp { src: u32, op: CmpOp, a: u8, b: u8, t_top: u32, t_pc: u32 },
    Goto { src: u32, t_top: u32, t_pc: u32 },
    GetField { src: u32, d: u8, o: u8, idx: u16 },
    PutField { src: u32, o: u8, idx: u16, s: u8 },
    GetStatic { src: u32, d: u8, class: u16, idx: u16 },
    ArrGet { src: u32, d: u8, arr: u8, idx: u8 },
    ArrPut { src: u32, arr: u8, idx: u8, s: u8 },
    ArrLen { src: u32, d: u8, arr: u8 },
    IntToFloat { src: u32, d: u8, s: u8 },
    FloatToInt { src: u32, d: u8, s: u8 },
    /// Fused `Const(c, k); IntBin(op, d, a, b)` — two charged
    /// components, one dispatch.
    ConstIntBin { src: u32, c: u8, k: i64, op: IntOp, d: u8, a: u8, b: u8 },
    /// Fused `IntBin(op, d, a, b); Goto` — the classic loop back edge.
    IntBinGoto { src: u32, op: IntOp, d: u8, a: u8, b: u8, t_top: u32, t_pc: u32 },
    /// Fused `Const; IntBin; Goto` — induction step + back edge.
    ConstIntBinGoto {
        src: u32,
        c: u8,
        k: i64,
        op: IntOp,
        d: u8,
        a: u8,
        b: u8,
        t_top: u32,
        t_pc: u32,
    },
    /// Heavy instruction: restore `frame.pc = src` (nothing charged) and
    /// hand control to the shared single-step.
    Bail { src: u32 },
}

/// A method's pre-decoded direct-threaded form.
#[derive(Debug)]
pub(crate) struct Translation {
    pub(crate) tops: Vec<TOp>,
    /// pc → index into `tops`; `NO_TOP` for fused interiors. Length is
    /// `code.len() + 1`: the end slot maps to a trailing [`TOp::Bail`]
    /// so running off the method end re-raises the interpreter's
    /// past-end fault from the cold path.
    pub(crate) pc_to_top: Vec<u32>,
    /// Segment entry requires `frame.regs.len() >= min_regs`; frames
    /// with fewer registers (possible only through a malformed capsule)
    /// run cold, where per-operand bounds checks fault exactly like the
    /// interpreter.
    pub(crate) min_regs: usize,
}

/// Promotion / translation-cache counters, drained per migration by
/// `execute_migration` into `CloneServeStats` (and from there into
/// `MetricsSnapshot` / `FarmStats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Methods that crossed the hotness threshold (first promotion per
    /// cache lifetime).
    pub promotions: u64,
    /// Successful translations (promotions minus untranslatable).
    pub translations: u64,
    /// Hot activations served from the translation cache.
    pub cache_hits: u64,
    /// Translations dropped by the FIFO bound.
    pub cache_evictions: u64,
    /// Instructions executed by translated segments (subset of
    /// `VmMetrics::instrs`, which both tiers charge identically).
    pub tier1_instrs: u64,
    /// Wall µs spent translating. Observe-only: translation charges no
    /// virtual time (it's the runtime's own cost, not the app's).
    pub translation_wall_us: u64,
}

impl TierStats {
    /// Drain: return the accumulated counters and reset to zero.
    pub fn take(&mut self) -> TierStats {
        std::mem::take(self)
    }
}

/// The execution tier of one clone process, selected by
/// `config.exec_tier`. `Interp` is the ablation baseline (and the only
/// tier the phone side ever uses); `Tier1` owns the profile state and
/// translation cache for one process.
#[derive(Debug)]
pub enum ExecTier {
    Interp,
    Tier1(Box<Tier1Engine>),
}

impl ExecTier {
    pub fn from_kind(kind: ExecTierKind) -> ExecTier {
        match kind {
            ExecTierKind::Interp => ExecTier::Interp,
            ExecTierKind::Tier1 => ExecTier::Tier1(Box::new(Tier1Engine::new())),
        }
    }

    pub fn kind(&self) -> ExecTierKind {
        match self {
            ExecTier::Interp => ExecTierKind::Interp,
            ExecTier::Tier1(_) => ExecTierKind::Tier1,
        }
    }

    /// Run thread `tid` until an exit condition — same contract (and
    /// bit-identical behaviour) as `interp::run_thread` with `NoHooks`.
    pub fn run_thread(&mut self, p: &mut Process, tid: u32, fuel: u64) -> Result<RunExit> {
        match self {
            ExecTier::Interp => interp::run_thread(p, tid, &mut NoHooks, fuel),
            ExecTier::Tier1(e) => e.run_thread(p, tid, fuel),
        }
    }

    /// Drain the tier counters (zero for the interpreter tier).
    pub fn take_stats(&mut self) -> TierStats {
        match self {
            ExecTier::Interp => TierStats::default(),
            ExecTier::Tier1(e) => e.stats.take(),
        }
    }
}

/// Profile state + translation cache for one process. Not shared across
/// processes: hotness is per clone session, and the cache is pinned to
/// one `Arc<Program>` identity.
#[derive(Debug)]
pub struct Tier1Engine {
    /// Activations of one method before it is promoted.
    threshold: u32,
    /// Alternative trigger: this many consecutively interpreted
    /// instructions inside one method (catches a single long-running
    /// activation, e.g. `main`'s scan loop on the first trip).
    instr_threshold: u64,
    /// Translation-cache bound (methods, FIFO eviction).
    cache_cap: usize,
    counts: HashMap<MRef, u32>,
    /// `None` = promoted but untranslatable (runs cold forever).
    cache: HashMap<MRef, Option<Arc<Translation>>>,
    order: VecDeque<MRef>,
    /// The program the cache was built against. Holding the `Arc` keeps
    /// the allocation alive, so `Arc::ptr_eq` is ABA-safe.
    program: Option<Arc<Program>>,
    stats: TierStats,
}

impl Default for Tier1Engine {
    fn default() -> Self {
        Tier1Engine::new()
    }
}

impl Tier1Engine {
    pub fn new() -> Tier1Engine {
        Tier1Engine {
            threshold: 2,
            instr_threshold: 64,
            cache_cap: 128,
            counts: HashMap::new(),
            cache: HashMap::new(),
            order: VecDeque::new(),
            program: None,
            stats: TierStats::default(),
        }
    }

    /// Activation-count promotion threshold (default 2).
    pub fn with_threshold(mut self, n: u32) -> Self {
        self.threshold = n.max(1);
        self
    }

    /// Translation-cache bound in methods (default 128).
    pub fn with_cache_cap(mut self, n: usize) -> Self {
        self.cache_cap = n.max(1);
        self
    }

    /// Counters accumulated since the last [`TierStats::take`].
    pub fn stats(&self) -> &TierStats {
        &self.stats
    }

    /// Run thread `tid` until an exit condition, executing hot
    /// translated spans directly and everything else through the shared
    /// single-step.
    pub fn run_thread(&mut self, p: &mut Process, tid: u32, fuel: u64) -> Result<RunExit> {
        let costs: CostParams = p.env_costs();
        let instr_cost = p.device.scale_us(costs.instr_us);
        let program = p.program.clone();
        let stale = match &self.program {
            Some(prev) => !Arc::ptr_eq(prev, &program),
            None => true,
        };
        if stale {
            self.cache.clear();
            self.counts.clear();
            self.order.clear();
            self.program = Some(program.clone());
        }

        let mut hooks = NoHooks;
        let mut spent: u64 = 0;
        let mut last_depth: usize = 0;
        let mut run_mref: Option<MRef> = None;
        let mut run_len: u64 = 0;

        loop {
            if spent >= fuel {
                return Ok(RunExit::OutOfFuel);
            }
            // Peek the current activation. Anything that is not a
            // runnable thread with a frame is the cold path's job — it
            // owns those exit/error semantics.
            let peek = {
                let t = p.thread(tid)?;
                if t.status == ThreadStatus::Runnable {
                    t.frames
                        .last()
                        .map(|f| (f.method, f.pc, f.regs.len(), t.frames.len()))
                } else {
                    None
                }
            };
            if let Some((mref, pc, regs_len, depth)) = peek {
                // Hotness profile: a new activation is a deeper stack
                // than last seen, or the first frame observed this run
                // (a resumed span counts as an entry).
                let entered = depth > last_depth || last_depth == 0;
                last_depth = depth;
                if run_mref != Some(mref) {
                    run_mref = Some(mref);
                    run_len = 0;
                }
                if entered {
                    let c = {
                        let e = self.counts.entry(mref).or_insert(0);
                        *e = e.saturating_add(1);
                        *e
                    };
                    if c >= self.threshold {
                        if self.cache.contains_key(&mref) {
                            self.stats.cache_hits += 1;
                        } else {
                            self.promote(&program, mref);
                        }
                    }
                } else if run_len == self.instr_threshold && !self.cache.contains_key(&mref) {
                    self.promote(&program, mref);
                }

                let tr = self.cache.get(&mref).and_then(|e| e.clone());
                if let Some(tr) = tr {
                    let start = tr.pc_to_top.get(pc).copied().unwrap_or(NO_TOP);
                    let enterable = start != NO_TOP
                        && !matches!(tr.tops[start as usize], TOp::Bail { .. })
                        && regs_len >= tr.min_regs;
                    if enterable {
                        match run_segment(
                            &tr,
                            start,
                            p,
                            tid,
                            &mut spent,
                            fuel,
                            instr_cost,
                            &mut self.stats,
                        )? {
                            SegExit::Exit(exit) => return Ok(exit),
                            // Re-check fuel/status/profile, then take
                            // the cold path for the bail pc.
                            SegExit::Bail => continue,
                        }
                    }
                }
            }
            // Cold path: exactly one shared-semantics step.
            match ops::step_one(p, &program, tid, &mut hooks, &costs, instr_cost)? {
                Some(exit) => return Ok(exit),
                None => {
                    spent += 1;
                    run_len += 1;
                }
            }
        }
    }

    /// Promote `mref`: translate (or record untranslatable) and insert
    /// into the bounded cache.
    fn promote(&mut self, program: &Program, mref: MRef) {
        self.stats.promotions += 1;
        let t0 = Instant::now();
        let tr = translate(program.method(mref), program);
        self.stats.translation_wall_us += t0.elapsed().as_micros() as u64;
        if tr.is_some() {
            self.stats.translations += 1;
        }
        if self.cache.len() >= self.cache_cap {
            if let Some(old) = self.order.pop_front() {
                self.cache.remove(&old);
                self.stats.cache_evictions += 1;
            }
        }
        self.order.push_back(mref);
        self.cache.insert(mref, tr.map(Arc::new));
    }
}

/// Why a segment returned control to the outer loop.
enum SegExit {
    /// A thread exit condition (completion can't happen in-segment —
    /// `Return` bails — so this is fuel or a partition point reached via
    /// cold re-entry; in practice only `OutOfFuel` originates here).
    Exit(RunExit),
    /// `frame.pc` points at an instruction the segment can't execute;
    /// the cold path takes exactly one step.
    Bail,
}

/// Translate one method, or `None` if any statically suspect
/// instruction makes lazy cold-path faulting the only safe behaviour.
pub(crate) fn translate(method: &MethodDef, program: &Program) -> Option<Translation> {
    let code = &method.code;
    let len = code.len();
    let nregs = method.nregs;

    // Pass 0: validate light ops, collect branch targets, bound regs.
    let mut is_target = vec![false; len + 1];
    let mut min_regs: usize = 0;
    {
        let mut reg = |r: u8, min_regs: &mut usize| {
            *min_regs = (*min_regs).max(r as usize + 1);
        };
        for ins in code {
            match ins {
                Instr::Nop => {}
                Instr::Const(d, _) | Instr::ConstF(d, _) => reg(*d, &mut min_regs),
                Instr::Move(d, s)
                | Instr::IntToFloat(d, s)
                | Instr::FloatToInt(d, s)
                | Instr::ArrLen(d, s) => {
                    reg(*d, &mut min_regs);
                    reg(*s, &mut min_regs);
                }
                Instr::IntBin(_, d, a, b)
                | Instr::FloatBin(_, d, a, b)
                | Instr::Cmp(_, d, a, b)
                | Instr::ArrGet(d, a, b)
                | Instr::ArrPut(d, a, b) => {
                    reg(*d, &mut min_regs);
                    reg(*a, &mut min_regs);
                    reg(*b, &mut min_regs);
                }
                Instr::IfZ(r, _) | Instr::IfNZ(r, _) => reg(*r, &mut min_regs),
                Instr::IfCmp(_, a, b, _) => {
                    reg(*a, &mut min_regs);
                    reg(*b, &mut min_regs);
                }
                Instr::Goto(_) => {}
                Instr::GetField(d, o, _) => {
                    reg(*d, &mut min_regs);
                    reg(*o, &mut min_regs);
                }
                Instr::PutField(o, _, s) => {
                    reg(*o, &mut min_regs);
                    reg(*s, &mut min_regs);
                }
                Instr::GetStatic(d, class, idx) => {
                    reg(*d, &mut min_regs);
                    let ok = program
                        .classes
                        .get(class.0 as usize)
                        .map_or(false, |c| (*idx as usize) < c.statics.len());
                    if !ok {
                        // The interpreter faults only if this executes;
                        // keep that laziness by not translating.
                        return None;
                    }
                }
                // Heavy ops bail to the cold path — their operands are
                // validated (lazily) there.
                Instr::Invoke { .. }
                | Instr::Return(_)
                | Instr::New(..)
                | Instr::PutStatic(..)
                | Instr::NewArray(..)
                | Instr::CcStart(_)
                | Instr::CcStop(_) => {}
            }
            if let Some(t) = ins.branch_target() {
                if (t as usize) > len {
                    // Taken, this branch faults on the next fetch; keep
                    // it lazy.
                    return None;
                }
                is_target[t as usize] = true;
            }
        }
    }
    if min_regs > nregs {
        // Some light op indexes past the frame — the interpreter faults
        // lazily when (and only when) it executes.
        return None;
    }

    // Pass 1: emit tops, fusing adjacent runs whose interiors are not
    // branch targets; branch `t_top`s are patched after.
    let mut tops: Vec<TOp> = Vec::with_capacity(len + 1);
    let mut pc_to_top = vec![NO_TOP; len + 1];
    let mut pc = 0usize;
    while pc < len {
        pc_to_top[pc] = tops.len() as u32;
        let src = pc as u32;
        let fuse2 = pc + 1 < len && !is_target[pc + 1];
        let fuse3 = pc + 2 < len && !is_target[pc + 1] && !is_target[pc + 2];
        if let Instr::Const(c, k) = code[pc] {
            if fuse2 {
                if let Instr::IntBin(op, d, a, b) = code[pc + 1] {
                    if fuse3 {
                        if let Instr::Goto(t) = code[pc + 2] {
                            tops.push(TOp::ConstIntBinGoto {
                                src,
                                c,
                                k,
                                op,
                                d,
                                a,
                                b,
                                t_top: 0,
                                t_pc: t,
                            });
                            pc += 3;
                            continue;
                        }
                    }
                    tops.push(TOp::ConstIntBin { src, c, k, op, d, a, b });
                    pc += 2;
                    continue;
                }
            }
        }
        if let Instr::IntBin(op, d, a, b) = code[pc] {
            if fuse2 {
                if let Instr::Goto(t) = code[pc + 1] {
                    tops.push(TOp::IntBinGoto {
                        src,
                        op,
                        d,
                        a,
                        b,
                        t_top: 0,
                        t_pc: t,
                    });
                    pc += 2;
                    continue;
                }
            }
        }
        let top = match &code[pc] {
            Instr::Nop => TOp::Nop { src },
            Instr::Const(d, v) => TOp::ConstI { src, d: *d, v: *v },
            Instr::ConstF(d, v) => TOp::ConstF { src, d: *d, v: *v },
            Instr::Move(d, s) => TOp::Move { src, d: *d, s: *s },
            Instr::IntBin(op, d, a, b) => TOp::IntBin {
                src,
                op: *op,
                d: *d,
                a: *a,
                b: *b,
            },
            Instr::FloatBin(op, d, a, b) => TOp::FloatBin {
                src,
                op: *op,
                d: *d,
                a: *a,
                b: *b,
            },
            Instr::Cmp(op, d, a, b) => TOp::Cmp {
                src,
                op: *op,
                d: *d,
                a: *a,
                b: *b,
            },
            Instr::IfZ(r, t) => TOp::IfZ {
                src,
                r: *r,
                t_top: 0,
                t_pc: *t,
            },
            Instr::IfNZ(r, t) => TOp::IfNZ {
                src,
                r: *r,
                t_top: 0,
                t_pc: *t,
            },
            Instr::IfCmp(op, a, b, t) => TOp::IfCmp {
                src,
                op: *op,
                a: *a,
                b: *b,
                t_top: 0,
                t_pc: *t,
            },
            Instr::Goto(t) => TOp::Goto {
                src,
                t_top: 0,
                t_pc: *t,
            },
            Instr::GetField(d, o, idx) => TOp::GetField {
                src,
                d: *d,
                o: *o,
                idx: *idx,
            },
            Instr::PutField(o, idx, s) => TOp::PutField {
                src,
                o: *o,
                idx: *idx,
                s: *s,
            },
            Instr::GetStatic(d, class, idx) => TOp::GetStatic {
                src,
                d: *d,
                class: class.0,
                idx: *idx,
            },
            Instr::ArrGet(d, arr, idx) => TOp::ArrGet {
                src,
                d: *d,
                arr: *arr,
                idx: *idx,
            },
            Instr::ArrPut(arr, idx, s) => TOp::ArrPut {
                src,
                arr: *arr,
                idx: *idx,
                s: *s,
            },
            Instr::ArrLen(d, arr) => TOp::ArrLen {
                src,
                d: *d,
                arr: *arr,
            },
            Instr::IntToFloat(d, s) => TOp::IntToFloat { src, d: *d, s: *s },
            Instr::FloatToInt(d, s) => TOp::FloatToInt { src, d: *d, s: *s },
            Instr::Invoke { .. }
            | Instr::Return(_)
            | Instr::New(..)
            | Instr::PutStatic(..)
            | Instr::NewArray(..)
            | Instr::CcStart(_)
            | Instr::CcStop(_) => TOp::Bail { src },
        };
        tops.push(top);
        pc += 1;
    }
    // Running off the end bails so the cold path raises the
    // interpreter's past-end fault verbatim.
    pc_to_top[len] = tops.len() as u32;
    tops.push(TOp::Bail { src: len as u32 });

    // Patch branch targets to translated-op indices. Every in-method
    // target has a top (fusion never swallows a branch target); a
    // method-end target resolves to the trailing bail.
    for top in &mut tops {
        match top {
            TOp::IfZ { t_top, t_pc, .. }
            | TOp::IfNZ { t_top, t_pc, .. }
            | TOp::IfCmp { t_top, t_pc, .. }
            | TOp::Goto { t_top, t_pc, .. }
            | TOp::IntBinGoto { t_top, t_pc, .. }
            | TOp::ConstIntBinGoto { t_top, t_pc, .. } => {
                let ti = pc_to_top[*t_pc as usize];
                if ti == NO_TOP {
                    return None;
                }
                *t_top = ti;
            }
            _ => {}
        }
    }

    Some(Translation {
        tops,
        pc_to_top,
        min_regs,
    })
}

/// Charge bookkeeping shared by every segment component: fuel gate,
/// virtual-clock charge, metrics, pc advance — byte-for-byte the
/// interpreter's per-instruction sequence.
struct SegCtx<'a> {
    clock: &'a mut VirtualClock,
    metrics: &'a mut VmMetrics,
    cpu_us: &'a mut f64,
    spent: &'a mut u64,
    fuel: u64,
    instr_cost: f64,
    stats: &'a mut TierStats,
}

impl SegCtx<'_> {
    /// Returns `false` when the fuel budget is exhausted — the component
    /// at `src` was NOT executed and `frame.pc` now points at it.
    #[inline(always)]
    fn charge(&mut self, frame: &mut Frame, src: u32) -> bool {
        if *self.spent >= self.fuel {
            frame.pc = src as usize;
            return false;
        }
        self.clock.charge_us(self.instr_cost);
        self.metrics.instrs += 1;
        *self.spent += 1;
        *self.cpu_us += self.instr_cost;
        self.stats.tier1_instrs += 1;
        frame.pc = src as usize + 1;
        true
    }
}

#[inline(always)]
fn ireg(frame: &Frame, r: u8) -> Result<i64> {
    frame.regs[r as usize]
        .as_int()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not an int")))
}

#[inline(always)]
fn freg(frame: &Frame, r: u8) -> Result<f64> {
    frame.regs[r as usize]
        .as_float()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not a float")))
}

#[inline(always)]
fn rref(frame: &Frame, r: u8) -> Result<ObjId> {
    frame.regs[r as usize]
        .as_ref()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not a reference (null deref?)")))
}

/// Execute translated ops starting at `start` until a bail, a fault, or
/// fuel exhaustion. Holds split borrows of the process for the whole
/// segment — no per-instruction thread lookups — while routing every
/// heap store through `Heap::get_mut` (the write barrier) exactly like
/// the interpreter.
#[allow(clippy::too_many_arguments)]
fn run_segment(
    tr: &Translation,
    start: u32,
    p: &mut Process,
    tid: u32,
    spent: &mut u64,
    fuel: u64,
    instr_cost: f64,
    stats: &mut TierStats,
) -> Result<SegExit> {
    let Process {
        ref mut heap,
        ref mut clock,
        ref mut metrics,
        ref mut threads,
        ref statics,
        ..
    } = *p;
    let Some(t) = threads.get_mut(tid as usize) else {
        return Ok(SegExit::Bail);
    };
    let VmThread {
        ref mut frames,
        ref mut cpu_us,
        ..
    } = *t;
    let Some(frame) = frames.last_mut() else {
        return Ok(SegExit::Bail);
    };

    let mut cx = SegCtx {
        clock,
        metrics,
        cpu_us,
        spent,
        fuel,
        instr_cost,
        stats,
    };

    macro_rules! fuel_gate {
        ($src:expr) => {
            if !cx.charge(frame, $src) {
                return Ok(SegExit::Exit(RunExit::OutOfFuel));
            }
        };
    }
    macro_rules! int_bin {
        ($op:expr, $d:expr, $a:expr, $b:expr) => {{
            let (x, y) = (ireg(frame, $a)?, ireg(frame, $b)?);
            let v =
                eval_int($op, x, y).ok_or_else(|| CloneCloudError::vm("division by zero"))?;
            frame.regs[$d as usize] = Value::Int(v);
        }};
    }

    let mut ti = start as usize;
    loop {
        let Some(top) = tr.tops.get(ti).copied() else {
            return Ok(SegExit::Bail);
        };
        match top {
            TOp::Nop { src } => {
                fuel_gate!(src);
            }
            TOp::ConstI { src, d, v } => {
                fuel_gate!(src);
                frame.regs[d as usize] = Value::Int(v);
            }
            TOp::ConstF { src, d, v } => {
                fuel_gate!(src);
                frame.regs[d as usize] = Value::Float(v);
            }
            TOp::Move { src, d, s } => {
                fuel_gate!(src);
                frame.regs[d as usize] = frame.regs[s as usize];
            }
            TOp::IntBin { src, op, d, a, b } => {
                fuel_gate!(src);
                int_bin!(op, d, a, b);
            }
            TOp::FloatBin { src, op, d, a, b } => {
                fuel_gate!(src);
                let (x, y) = (freg(frame, a)?, freg(frame, b)?);
                frame.regs[d as usize] = Value::Float(eval_float(op, x, y));
            }
            TOp::Cmp { src, op, d, a, b } => {
                fuel_gate!(src);
                let r = ops::cmp_values(op, frame.regs[a as usize], frame.regs[b as usize])?;
                frame.regs[d as usize] = Value::Int(r as i64);
            }
            TOp::IfZ { src, r, t_top, t_pc } => {
                fuel_gate!(src);
                if !frame.regs[r as usize].is_truthy() {
                    frame.pc = t_pc as usize;
                    ti = t_top as usize;
                    continue;
                }
            }
            TOp::IfNZ { src, r, t_top, t_pc } => {
                fuel_gate!(src);
                if frame.regs[r as usize].is_truthy() {
                    frame.pc = t_pc as usize;
                    ti = t_top as usize;
                    continue;
                }
            }
            TOp::IfCmp {
                src,
                op,
                a,
                b,
                t_top,
                t_pc,
            } => {
                fuel_gate!(src);
                if ops::cmp_values(op, frame.regs[a as usize], frame.regs[b as usize])? {
                    frame.pc = t_pc as usize;
                    ti = t_top as usize;
                    continue;
                }
            }
            TOp::Goto { src, t_top, t_pc } => {
                fuel_gate!(src);
                frame.pc = t_pc as usize;
                ti = t_top as usize;
                continue;
            }
            TOp::GetField { src, d, o, idx } => {
                fuel_gate!(src);
                let oid = rref(frame, o)?;
                let obj = heap.get(oid)?;
                let v = match &obj.body {
                    ObjBody::Fields(fs) => *fs.get(idx as usize).ok_or_else(|| {
                        CloneCloudError::vm(format!("field index {idx} out of range"))
                    })?,
                    _ => return Err(CloneCloudError::vm("getfield on array")),
                };
                frame.regs[d as usize] = v;
            }
            TOp::PutField { src, o, idx, s } => {
                fuel_gate!(src);
                let v = frame.regs[s as usize];
                let oid = rref(frame, o)?;
                let obj = heap.get_mut(oid)?;
                match &mut obj.body {
                    ObjBody::Fields(fs) => {
                        let slot = fs.get_mut(idx as usize).ok_or_else(|| {
                            CloneCloudError::vm(format!("field index {idx} out of range"))
                        })?;
                        *slot = v;
                    }
                    _ => return Err(CloneCloudError::vm("putfield on array")),
                }
            }
            TOp::GetStatic { src, d, class, idx } => {
                fuel_gate!(src);
                let v = *statics
                    .get(class as usize)
                    .and_then(|s| s.get(idx as usize))
                    .ok_or_else(|| CloneCloudError::vm("static index out of range"))?;
                frame.regs[d as usize] = v;
            }
            TOp::ArrGet { src, d, arr, idx } => {
                fuel_gate!(src);
                let oid = rref(frame, arr)?;
                let i = ireg(frame, idx)? as usize;
                let v = match &heap.get(oid)?.body {
                    ObjBody::ByteArray(b) => {
                        Value::Int(*b.get(i).ok_or_else(ops::oob)? as i64)
                    }
                    ObjBody::FloatArray(f) => {
                        Value::Float(*f.get(i).ok_or_else(ops::oob)? as f64)
                    }
                    ObjBody::RefArray(v) => *v.get(i).ok_or_else(ops::oob)?,
                    ObjBody::Fields(_) => {
                        return Err(CloneCloudError::vm("arrget on object"))
                    }
                };
                frame.regs[d as usize] = v;
            }
            TOp::ArrPut { src, arr, idx, s } => {
                fuel_gate!(src);
                let v = frame.regs[s as usize];
                let oid = rref(frame, arr)?;
                let i = ireg(frame, idx)? as usize;
                match &mut heap.get_mut(oid)?.body {
                    ObjBody::ByteArray(b) => {
                        let slot = b.get_mut(i).ok_or_else(ops::oob)?;
                        *slot = v.as_int().ok_or_else(|| {
                            CloneCloudError::vm("byte array stores require ints")
                        })? as u8;
                    }
                    ObjBody::FloatArray(f) => {
                        let slot = f.get_mut(i).ok_or_else(ops::oob)?;
                        *slot = v.as_float().ok_or_else(|| {
                            CloneCloudError::vm("float array stores require numbers")
                        })? as f32;
                    }
                    ObjBody::RefArray(rv) => {
                        let slot = rv.get_mut(i).ok_or_else(ops::oob)?;
                        *slot = v;
                    }
                    ObjBody::Fields(_) => {
                        return Err(CloneCloudError::vm("arrput on object"))
                    }
                }
            }
            TOp::ArrLen { src, d, arr } => {
                fuel_gate!(src);
                let oid = rref(frame, arr)?;
                let len = match &heap.get(oid)?.body {
                    ObjBody::ByteArray(b) => b.len(),
                    ObjBody::FloatArray(f) => f.len(),
                    ObjBody::RefArray(v) => v.len(),
                    ObjBody::Fields(_) => {
                        return Err(CloneCloudError::vm("arrlen on object"))
                    }
                };
                frame.regs[d as usize] = Value::Int(len as i64);
            }
            TOp::IntToFloat { src, d, s } => {
                fuel_gate!(src);
                let v = ireg(frame, s)?;
                frame.regs[d as usize] = Value::Float(v as f64);
            }
            TOp::FloatToInt { src, d, s } => {
                fuel_gate!(src);
                let v = freg(frame, s)?;
                frame.regs[d as usize] = Value::Int(v as i64);
            }
            TOp::ConstIntBin {
                src,
                c,
                k,
                op,
                d,
                a,
                b,
            } => {
                fuel_gate!(src);
                frame.regs[c as usize] = Value::Int(k);
                fuel_gate!(src + 1);
                int_bin!(op, d, a, b);
            }
            TOp::IntBinGoto {
                src,
                op,
                d,
                a,
                b,
                t_top,
                t_pc,
            } => {
                fuel_gate!(src);
                int_bin!(op, d, a, b);
                fuel_gate!(src + 1);
                frame.pc = t_pc as usize;
                ti = t_top as usize;
                continue;
            }
            TOp::ConstIntBinGoto {
                src,
                c,
                k,
                op,
                d,
                a,
                b,
                t_top,
                t_pc,
            } => {
                fuel_gate!(src);
                frame.regs[c as usize] = Value::Int(k);
                fuel_gate!(src + 1);
                int_bin!(op, d, a, b);
                fuel_gate!(src + 2);
                frame.pc = t_pc as usize;
                ti = t_top as usize;
                continue;
            }
            TOp::Bail { src } => {
                frame.pc = src as usize;
                return Ok(SegExit::Bail);
            }
        }
        ti += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::bytecode::ClassId;
    use crate::appvm::class::ClassDef;
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::value::Value;
    use crate::device::{DeviceSpec, Location};
    use crate::vfs::SimFs;

    fn program_with_main(code: Vec<Instr>, nregs: usize) -> Arc<Program> {
        let mut p = Program::new();
        let mut c = ClassDef::new("App", false);
        c.add_static("s");
        c.add_method(MethodDef {
            name: "main".into(),
            nargs: 0,
            nregs,
            code,
            native: None,
            pinned: true,
            native_state: false,
            migration_point: None,
        });
        p.add_class(c);
        p.into_shared()
    }

    fn process(program: &Arc<Program>) -> Process {
        let mut p = Process::new(
            program.clone(),
            DeviceSpec::clone_desktop(),
            Location::Clone,
            NodeEnv::with_rust_compute(SimFs::new()),
        );
        let main = program.entry().unwrap();
        p.spawn_thread(main, &[]).unwrap();
        p
    }

    /// Sum loop with a `Const`+`IntBin` pair in the body:
    ///   3: Const r3 1 ; 4: add r1 r1 r3 ; 5: add r0 r0 r1 ;
    ///   6: iflt r1 r2 -> 3 ; 7: ret r0
    fn sum_kernel(limit: i64) -> Vec<Instr> {
        vec![
            Instr::Const(0, 0),
            Instr::Const(1, 0),
            Instr::Const(2, limit),
            Instr::Const(3, 1),
            Instr::IntBin(IntOp::Add, 1, 1, 3),
            Instr::IntBin(IntOp::Add, 0, 0, 1),
            Instr::IfCmp(CmpOp::Lt, 1, 2, 4),
            Instr::Return(Some(0)),
        ]
    }

    /// Back-edge kernel exercising `Const`+`IntBin`+`Goto` fusion:
    ///   3: ifge r1 r2 -> 8 ; 4: add r0 r0 r1 ;
    ///   5: Const r3 1 ; 6: add r1 r1 r3 ; 7: goto 3 ; 8: ret r0
    fn goto_kernel(limit: i64) -> Vec<Instr> {
        vec![
            Instr::Const(0, 0),
            Instr::Const(1, 0),
            Instr::Const(2, limit),
            Instr::IfCmp(CmpOp::Ge, 1, 2, 8),
            Instr::IntBin(IntOp::Add, 0, 0, 1),
            Instr::Const(3, 1),
            Instr::IntBin(IntOp::Add, 1, 1, 3),
            Instr::Goto(3),
            Instr::Return(Some(0)),
        ]
    }

    fn fingerprint(p: &Process) -> (u64, u64, f64, f64) {
        let t = p.thread(0).unwrap();
        (
            p.metrics.instrs,
            p.clock.now_us().to_bits(),
            t.cpu_us,
            t.frames.last().map_or(-1.0, |f| f.pc as f64),
        )
    }

    fn run_both(code: Vec<Instr>, nregs: usize, fuel: u64) -> (Result<RunExit>, Result<RunExit>) {
        let prog = program_with_main(code, nregs);
        let mut base = process(&prog);
        let r0 = interp::run_thread(&mut base, 0, &mut NoHooks, fuel);
        let mut tiered = process(&prog);
        let mut tier = ExecTier::Tier1(Box::new(Tier1Engine::new().with_threshold(1)));
        let r1 = tier.run_thread(&mut tiered, 0, fuel);
        assert_eq!(fingerprint(&base), fingerprint(&tiered), "state fingerprint");
        (r0, r1)
    }

    #[test]
    fn translation_fuses_and_maps_interiors() {
        let prog = program_with_main(goto_kernel(10), 4);
        let main = prog.entry().unwrap();
        let tr = translate(prog.method(main), &prog).expect("translatable");
        assert!(tr
            .tops
            .iter()
            .any(|t| matches!(t, TOp::ConstIntBinGoto { .. })));
        // Fused interiors (pcs 6, 7) have no top of their own.
        assert_eq!(tr.pc_to_top[6], NO_TOP);
        assert_eq!(tr.pc_to_top[7], NO_TOP);
        // The loop head is a real entry and branch targets resolve.
        assert_ne!(tr.pc_to_top[3], NO_TOP);
        assert_eq!(tr.min_regs, 4);
        // Return is a bail; the end slot maps to the trailing bail.
        assert!(matches!(tr.tops[tr.pc_to_top[8] as usize], TOp::Bail { .. }));
        assert!(matches!(
            tr.tops[tr.pc_to_top[9] as usize],
            TOp::Bail { .. }
        ));
    }

    #[test]
    fn tier1_matches_interp_on_loop_kernels() {
        let (r0, r1) = run_both(sum_kernel(100), 4, u64::MAX);
        assert_eq!(
            r0.unwrap(),
            RunExit::Completed(Some(Value::Int(5050)))
        );
        assert_eq!(r1.unwrap(), RunExit::Completed(Some(Value::Int(5050))));

        let (r0, r1) = run_both(goto_kernel(50), 4, u64::MAX);
        assert_eq!(
            r0.unwrap(),
            RunExit::Completed(Some(Value::Int(1225)))
        );
        assert_eq!(r1.unwrap(), RunExit::Completed(Some(Value::Int(1225))));
    }

    #[test]
    fn fuel_exhaustion_is_bit_identical_even_mid_fusion() {
        // Fuel values land on every phase of the fused bodies, including
        // interiors; resuming from an interior pc cold-steps back onto a
        // translated boundary.
        for fuel in 1..40u64 {
            let prog = program_with_main(goto_kernel(6), 4);
            let mut base = process(&prog);
            let r0 = interp::run_thread(&mut base, 0, &mut NoHooks, fuel).unwrap();
            let mut tiered = process(&prog);
            let mut tier = ExecTier::Tier1(Box::new(Tier1Engine::new().with_threshold(1)));
            let r1 = tier.run_thread(&mut tiered, 0, fuel).unwrap();
            assert_eq!(r0, r1, "exit at fuel {fuel}");
            assert_eq!(
                fingerprint(&base),
                fingerprint(&tiered),
                "state at fuel {fuel}"
            );
            // Resume both to completion; results must still agree.
            let r0 = interp::run_thread(&mut base, 0, &mut NoHooks, u64::MAX).unwrap();
            let r1 = tier.run_thread(&mut tiered, 0, u64::MAX).unwrap();
            assert_eq!(r0, r1, "resumed exit at fuel {fuel}");
            assert_eq!(fingerprint(&base), fingerprint(&tiered));
        }
    }

    #[test]
    fn faults_match_the_interpreter() {
        // Division by zero inside a translated segment.
        let code = vec![
            Instr::Const(0, 7),
            Instr::Const(1, 0),
            Instr::IntBin(IntOp::Div, 2, 0, 1),
            Instr::Return(Some(2)),
        ];
        let prog = program_with_main(code, 3);
        let mut base = process(&prog);
        let e0 = interp::run_thread(&mut base, 0, &mut NoHooks, u64::MAX).unwrap_err();
        let mut tiered = process(&prog);
        let mut tier = ExecTier::Tier1(Box::new(Tier1Engine::new().with_threshold(1)));
        let e1 = tier.run_thread(&mut tiered, 0, u64::MAX).unwrap_err();
        assert_eq!(e0.to_string(), e1.to_string());
        assert_eq!(fingerprint(&base), fingerprint(&tiered), "pc past fault");

        // A light op indexing past the frame: untranslatable, faults
        // identically from the cold path.
        let code = vec![Instr::Const(200, 1), Instr::Return(None)];
        let prog = program_with_main(code, 2);
        let main = prog.entry().unwrap();
        assert!(translate(prog.method(main), &prog).is_none());
        let mut base = process(&prog);
        let e0 = interp::run_thread(&mut base, 0, &mut NoHooks, u64::MAX).unwrap_err();
        let mut tiered = process(&prog);
        let mut tier = ExecTier::Tier1(Box::new(Tier1Engine::new().with_threshold(1)));
        let e1 = tier.run_thread(&mut tiered, 0, u64::MAX).unwrap_err();
        assert_eq!(e0.to_string(), e1.to_string());
    }

    #[test]
    fn cache_invalidated_when_program_changes() {
        let prog_a = program_with_main(sum_kernel(10), 4);
        let mut engine = Tier1Engine::new().with_threshold(1);
        let mut pa = process(&prog_a);
        engine.run_thread(&mut pa, 0, u64::MAX).unwrap();
        assert_eq!(engine.stats().translations, 1);

        // Same bytecode, different Arc identity: the cache must rebuild.
        let prog_b = program_with_main(sum_kernel(10), 4);
        let mut pb = process(&prog_b);
        engine.run_thread(&mut pb, 0, u64::MAX).unwrap();
        assert_eq!(engine.stats().translations, 2, "stale cache reused");
        assert!(engine.stats().tier1_instrs > 0);
    }

    #[test]
    fn cache_bound_evicts_fifo() {
        // main + helper both hot, cache capped at one translation.
        let mut p = Program::new();
        let mut c = ClassDef::new("App", false);
        let helper_code = sum_kernel(5);
        c.add_method(MethodDef {
            name: "main".into(),
            nargs: 0,
            nregs: 2,
            code: vec![
                Instr::Const(0, 0),
                // 1: call helper twice so both cross threshold 1.
                Instr::Invoke {
                    mref: MRef {
                        class: ClassId(0),
                        method: crate::appvm::bytecode::MethodId(1),
                    },
                    ret: Some(1),
                    args: vec![],
                },
                Instr::Invoke {
                    mref: MRef {
                        class: ClassId(0),
                        method: crate::appvm::bytecode::MethodId(1),
                    },
                    ret: Some(1),
                    args: vec![],
                },
                Instr::Return(Some(1)),
            ],
            native: None,
            pinned: true,
            native_state: false,
            migration_point: None,
        });
        c.add_method(MethodDef {
            name: "helper".into(),
            nargs: 0,
            nregs: 4,
            code: helper_code,
            native: None,
            pinned: false,
            native_state: false,
            migration_point: None,
        });
        p.add_class(c);
        let prog = p.into_shared();
        let mut proc = process(&prog);
        let mut engine = Tier1Engine::new().with_threshold(1).with_cache_cap(1);
        let r = engine.run_thread(&mut proc, 0, u64::MAX).unwrap();
        assert_eq!(r, RunExit::Completed(Some(Value::Int(15))));
        assert!(engine.stats().cache_evictions >= 1, "{:?}", engine.stats());
    }
}
