//! DroidVM instruction set.
//!
//! A register-based bytecode modeled after Dalvik (the paper's target VM):
//! each method owns a flat register file; instructions reference registers
//! by index. Two instructions are special to CloneCloud — `CcStart` and
//! `CcStop` — the migration / reintegration points the partitioner's
//! rewriter inserts at chosen method entries and exits (paper §5).

use std::fmt;

/// Class index into the program's Method Area.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClassId(pub u16);

/// Method index within its class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MethodId(pub u16);

/// Global method reference.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MRef {
    pub class: ClassId,
    pub method: MethodId,
}

// MRef display needs the program for names; the raw form shows indices.
impl fmt::Display for MRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}.{}", self.class.0, self.method.0)
    }
}

/// Register index within a frame.
pub type Reg = u8;

/// Integer binary operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Shl,
    Shr,
}

/// Float binary operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FloatOp {
    Add,
    Sub,
    Mul,
    Div,
}

/// Comparison operations (int or float operands).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Eq,
    Ne,
    Ge,
    Gt,
}

/// Array element kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrKind {
    /// Packed bytes (file contents, images).
    Byte,
    /// Packed f32 (keyword vectors, scores).
    Float,
    /// Boxed values (object references or ints).
    Val,
}

/// One DroidVM instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Instr {
    Nop,
    /// dst <- integer constant
    Const(Reg, i64),
    /// dst <- float constant
    ConstF(Reg, f64),
    /// dst <- src
    Move(Reg, Reg),
    /// dst <- a op b (integers)
    IntBin(IntOp, Reg, Reg, Reg),
    /// dst <- a op b (floats)
    FloatBin(FloatOp, Reg, Reg, Reg),
    /// dst <- (a op b) ? 1 : 0
    Cmp(CmpOp, Reg, Reg, Reg),
    /// branch to target if reg == 0
    IfZ(Reg, u32),
    /// branch to target if reg != 0
    IfNZ(Reg, u32),
    /// branch to target if (a op b)
    IfCmp(CmpOp, Reg, Reg, u32),
    /// unconditional branch
    Goto(u32),
    /// call `mref` with argument registers; optional return register
    Invoke {
        mref: MRef,
        ret: Option<Reg>,
        args: Vec<Reg>,
    },
    /// return (with optional value register)
    Return(Option<Reg>),
    /// dst <- new instance of class
    New(Reg, ClassId),
    /// dst <- obj.field[idx]
    GetField(Reg, Reg, u16),
    /// obj.field[idx] <- src
    PutField(Reg, u16, Reg),
    /// dst <- Class.static[idx]
    GetStatic(Reg, ClassId, u16),
    /// Class.static[idx] <- src
    PutStatic(ClassId, u16, Reg),
    /// dst <- new array of kind with length from register
    NewArray(Reg, ArrKind, Reg),
    /// dst <- arr[idx]
    ArrGet(Reg, Reg, Reg),
    /// arr[idx] <- src
    ArrPut(Reg, Reg, Reg),
    /// dst <- arr.length
    ArrLen(Reg, Reg),
    /// dst <- (float) src
    IntToFloat(Reg, Reg),
    /// dst <- (int) src, truncating
    FloatToInt(Reg, Reg),
    /// Migration point (inserted by the rewriter). The operand is the
    /// partition-point id, used to look up the policy decision.
    CcStart(u32),
    /// Reintegration point (inserted by the rewriter).
    CcStop(u32),
}

impl Instr {
    /// Branch target, if this is a branch.
    pub fn branch_target(&self) -> Option<u32> {
        match self {
            Instr::IfZ(_, t) | Instr::IfNZ(_, t) | Instr::IfCmp(_, _, _, t) | Instr::Goto(t) => {
                Some(*t)
            }
            _ => None,
        }
    }

    /// The method this instruction calls, if it is an invoke.
    pub fn callee(&self) -> Option<MRef> {
        match self {
            Instr::Invoke { mref, .. } => Some(*mref),
            _ => None,
        }
    }
}

/// Apply an integer binary op with VM wrap semantics; `Div`/`Rem` by zero
/// are surfaced as `None` (the interpreter raises a VM fault).
pub fn eval_int(op: IntOp, a: i64, b: i64) -> Option<i64> {
    Some(match op {
        IntOp::Add => a.wrapping_add(b),
        IntOp::Sub => a.wrapping_sub(b),
        IntOp::Mul => a.wrapping_mul(b),
        IntOp::Div => {
            if b == 0 {
                return None;
            }
            a.wrapping_div(b)
        }
        IntOp::Rem => {
            if b == 0 {
                return None;
            }
            a.wrapping_rem(b)
        }
        IntOp::And => a & b,
        IntOp::Or => a | b,
        IntOp::Xor => a ^ b,
        IntOp::Shl => a.wrapping_shl((b & 63) as u32),
        IntOp::Shr => a.wrapping_shr((b & 63) as u32),
    })
}

/// Apply a float binary op.
pub fn eval_float(op: FloatOp, a: f64, b: f64) -> f64 {
    match op {
        FloatOp::Add => a + b,
        FloatOp::Sub => a - b,
        FloatOp::Mul => a * b,
        FloatOp::Div => a / b,
    }
}

/// Apply a comparison.
pub fn eval_cmp_i(op: CmpOp, a: i64, b: i64) -> bool {
    match op {
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Ge => a >= b,
        CmpOp::Gt => a > b,
    }
}

pub fn eval_cmp_f(op: CmpOp, a: f64, b: f64) -> bool {
    match op {
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Ge => a >= b,
        CmpOp::Gt => a > b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_ops() {
        assert_eq!(eval_int(IntOp::Add, 2, 3), Some(5));
        assert_eq!(eval_int(IntOp::Div, 7, 2), Some(3));
        assert_eq!(eval_int(IntOp::Div, 1, 0), None);
        assert_eq!(eval_int(IntOp::Rem, 1, 0), None);
        assert_eq!(eval_int(IntOp::Add, i64::MAX, 1), Some(i64::MIN), "wraps");
        assert_eq!(eval_int(IntOp::Shl, 1, 4), Some(16));
    }

    #[test]
    fn cmp_ops() {
        assert!(eval_cmp_i(CmpOp::Lt, 1, 2));
        assert!(!eval_cmp_i(CmpOp::Gt, 1, 2));
        assert!(eval_cmp_f(CmpOp::Ge, 2.0, 2.0));
        assert!(eval_cmp_f(CmpOp::Ne, 1.0, 2.0));
    }

    #[test]
    fn branch_target_extraction() {
        assert_eq!(Instr::Goto(7).branch_target(), Some(7));
        assert_eq!(Instr::Nop.branch_target(), None);
        assert_eq!(Instr::IfZ(0, 3).branch_target(), Some(3));
    }

    #[test]
    fn callee_extraction() {
        let m = MRef {
            class: ClassId(1),
            method: MethodId(2),
        };
        let i = Instr::Invoke {
            mref: m,
            ret: None,
            args: vec![0],
        };
        assert_eq!(i.callee(), Some(m));
        assert_eq!(Instr::Nop.callee(), None);
    }
}
