//! The Method Area: classes, fields, methods, and the loaded program.
//!
//! Mirrors the application-VM model of the paper's §2: a program is a blob
//! of bytecode organized into classes; the VM-wide Method Area holds the
//! types and static-variable layout. Methods carry the annotations the
//! partitioner's static analysis consumes: `pinned` (the V_M set,
//! Property 1), `native_state` (the V_Nat_C sets, Property 2), and
//! `system` on the class (system methods are not partition candidates).

use std::collections::HashMap;
use std::sync::Arc;

use super::bytecode::{ClassId, Instr, MRef, MethodId};
use crate::error::{CloneCloudError, Result};

/// Identifies a registered native implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NativeId(pub u16);

/// A method definition.
#[derive(Debug, Clone, PartialEq)]
pub struct MethodDef {
    pub name: String,
    /// Number of arguments; they arrive in registers `[0, nargs)`.
    pub nargs: usize,
    /// Total registers in the frame (>= nargs).
    pub nregs: usize,
    /// Bytecode; empty for native methods.
    pub code: Vec<Instr>,
    /// Native implementation, if this is a native method.
    pub native: Option<NativeId>,
    /// Property 1 (V_M): pinned to the mobile device — accesses a
    /// device-unique resource (GPS, camera, UI) or is `main`.
    pub pinned: bool,
    /// Property 2: creates/accesses native state below the VM; all such
    /// methods of one class form a V_Nat_C collocation group.
    pub native_state: bool,
    /// Set by the rewriter: this method is a migration point R(m)=1,
    /// with the given partition-point id.
    pub migration_point: Option<u32>,
}

impl MethodDef {
    pub fn is_native(&self) -> bool {
        self.native.is_some()
    }
}

/// A class definition.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassDef {
    pub name: String,
    /// System classes (core library, Zygote-warmed types) are excluded
    /// from partitioning; only application classes get R(m) variables.
    pub system: bool,
    /// Instance field names; object field storage is positional.
    pub fields: Vec<String>,
    /// Static field names; storage lives in `Process::statics`.
    pub statics: Vec<String>,
    pub methods: Vec<MethodDef>,
    method_index: HashMap<String, MethodId>,
    field_index: HashMap<String, u16>,
    static_index: HashMap<String, u16>,
}

impl ClassDef {
    pub fn new(name: &str, system: bool) -> ClassDef {
        ClassDef {
            name: name.to_string(),
            system,
            fields: Vec::new(),
            statics: Vec::new(),
            methods: Vec::new(),
            method_index: HashMap::new(),
            field_index: HashMap::new(),
            static_index: HashMap::new(),
        }
    }

    pub fn add_field(&mut self, name: &str) -> u16 {
        let idx = self.fields.len() as u16;
        self.fields.push(name.to_string());
        self.field_index.insert(name.to_string(), idx);
        idx
    }

    pub fn add_static(&mut self, name: &str) -> u16 {
        let idx = self.statics.len() as u16;
        self.statics.push(name.to_string());
        self.static_index.insert(name.to_string(), idx);
        idx
    }

    pub fn add_method(&mut self, m: MethodDef) -> MethodId {
        let id = MethodId(self.methods.len() as u16);
        self.method_index.insert(m.name.clone(), id);
        self.methods.push(m);
        id
    }

    pub fn method_id(&self, name: &str) -> Option<MethodId> {
        self.method_index.get(name).copied()
    }

    pub fn field_id(&self, name: &str) -> Option<u16> {
        self.field_index.get(name).copied()
    }

    pub fn static_id(&self, name: &str) -> Option<u16> {
        self.static_index.get(name).copied()
    }
}

/// A loaded program: the immutable Method Area shared by phone and clone
/// processes (`Arc`; the clone receives the same executable through the
/// node manager's file-system synchronization).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Program {
    pub classes: Vec<ClassDef>,
    class_index: HashMap<String, ClassId>,
}

impl Program {
    pub fn new() -> Program {
        Program::default()
    }

    pub fn add_class(&mut self, c: ClassDef) -> ClassId {
        let id = ClassId(self.classes.len() as u16);
        self.class_index.insert(c.name.clone(), id);
        self.classes.push(c);
        id
    }

    pub fn class_id(&self, name: &str) -> Option<ClassId> {
        self.class_index.get(name).copied()
    }

    pub fn class(&self, id: ClassId) -> &ClassDef {
        &self.classes[id.0 as usize]
    }

    pub fn class_mut(&mut self, id: ClassId) -> &mut ClassDef {
        &mut self.classes[id.0 as usize]
    }

    pub fn method(&self, mref: MRef) -> &MethodDef {
        &self.class(mref.class).methods[mref.method.0 as usize]
    }

    pub fn method_mut(&mut self, mref: MRef) -> &mut MethodDef {
        &mut self.classes[mref.class.0 as usize].methods[mref.method.0 as usize]
    }

    /// Resolve "Class.method" to an MRef.
    pub fn resolve(&self, class: &str, method: &str) -> Result<MRef> {
        let cid = self
            .class_id(class)
            .ok_or_else(|| CloneCloudError::program(format!("no class '{class}'")))?;
        let mid = self
            .class(cid)
            .method_id(method)
            .ok_or_else(|| CloneCloudError::program(format!("no method '{class}.{method}'")))?;
        Ok(MRef {
            class: cid,
            method: mid,
        })
    }

    /// Human-readable method name.
    pub fn method_name(&self, mref: MRef) -> String {
        format!(
            "{}.{}",
            self.class(mref.class).name,
            self.method(mref).name
        )
    }

    /// The program entry point: the unique `main` on an app class.
    pub fn entry(&self) -> Result<MRef> {
        for (ci, c) in self.classes.iter().enumerate() {
            if c.system {
                continue;
            }
            if let Some(mid) = c.method_id("main") {
                return Ok(MRef {
                    class: ClassId(ci as u16),
                    method: mid,
                });
            }
        }
        Err(CloneCloudError::program("no app main method"))
    }

    /// All methods, in deterministic order.
    pub fn all_methods(&self) -> Vec<MRef> {
        let mut out = Vec::new();
        for (ci, c) in self.classes.iter().enumerate() {
            for mi in 0..c.methods.len() {
                out.push(MRef {
                    class: ClassId(ci as u16),
                    method: MethodId(mi as u16),
                });
            }
        }
        out
    }

    /// App (non-system) methods — the partition candidates.
    pub fn app_methods(&self) -> Vec<MRef> {
        self.all_methods()
            .into_iter()
            .filter(|m| !self.class(m.class).system)
            .collect()
    }

    /// The migration points a rewritten binary carries, as
    /// (point id, method) pairs sorted by point id. Empty for an
    /// unrewritten program. The runtime policy layer treats this as the
    /// authoritative pid ↔ method map — the binary IS the map.
    pub fn migration_points(&self) -> Vec<(u32, MRef)> {
        let mut out: Vec<(u32, MRef)> = self
            .all_methods()
            .into_iter()
            .filter_map(|m| self.method(m).migration_point.map(|pid| (pid, m)))
            .collect();
        out.sort_unstable_by_key(|&(pid, _)| pid);
        out
    }

    pub fn into_shared(self) -> Arc<Program> {
        Arc::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Program {
        let mut p = Program::new();
        let mut c = ClassDef::new("A", false);
        c.add_field("x");
        c.add_static("s");
        c.add_method(MethodDef {
            name: "main".into(),
            nargs: 0,
            nregs: 2,
            code: vec![Instr::Return(None)],
            native: None,
            pinned: true,
            native_state: false,
            migration_point: None,
        });
        p.add_class(c);
        let mut sys = ClassDef::new("java.lang.Object", true);
        sys.add_method(MethodDef {
            name: "init".into(),
            nargs: 0,
            nregs: 1,
            code: vec![Instr::Return(None)],
            native: None,
            pinned: false,
            native_state: false,
            migration_point: None,
        });
        p.add_class(sys);
        p
    }

    #[test]
    fn resolve_and_names() {
        let p = sample();
        let m = p.resolve("A", "main").unwrap();
        assert_eq!(p.method_name(m), "A.main");
        assert!(p.resolve("A", "nope").is_err());
        assert!(p.resolve("B", "main").is_err());
    }

    #[test]
    fn entry_finds_app_main() {
        let p = sample();
        let e = p.entry().unwrap();
        assert_eq!(p.method_name(e), "A.main");
    }

    #[test]
    fn app_methods_exclude_system() {
        let p = sample();
        assert_eq!(p.all_methods().len(), 2);
        assert_eq!(p.app_methods().len(), 1);
    }

    #[test]
    fn migration_points_read_back_sorted() {
        let mut p = sample();
        assert!(p.migration_points().is_empty(), "unrewritten binary");
        let m = p.resolve("A", "main").unwrap();
        p.method_mut(m).migration_point = Some(7);
        assert_eq!(p.migration_points(), vec![(7, m)]);
    }

    #[test]
    fn field_and_static_ids() {
        let p = sample();
        let c = p.class(p.class_id("A").unwrap());
        assert_eq!(c.field_id("x"), Some(0));
        assert_eq!(c.static_id("s"), Some(0));
        assert_eq!(c.field_id("y"), None);
    }
}
