//! Native interface framework.
//!
//! The paper's §2: "external processing such as file I/O, networking,
//! using local hardware ... punch through the abstract machine". DroidVM
//! natives come in two flavors, the distinction CloneCloud's Property 1
//! is built on:
//!
//! * **pinned** natives (`ui.*`, `sensor.*`) touch device-unique hardware
//!   and form the V_M set — they may only run on the mobile device;
//! * **everywhere** natives (`fs.*` over the synchronized file system,
//!   `compute.*` backed by the PJRT artifacts) exist on both devices —
//!   the paper's distinguishing "native everywhere" feature.
//!
//! Compute natives delegate to a [`ComputeBackend`]: the production
//! implementation loads the AOT HLO artifacts through PJRT
//! (`runtime::PjrtCompute`); a pure-Rust reference (`RustCompute`) keeps
//! unit tests hermetic and cross-checks PJRT numerics.

use std::collections::HashMap;
use std::sync::Arc;

use super::class::NativeId;
use super::heap::Heap;
use super::value::{ObjBody, Value};
use crate::clock::VirtualClock;
use crate::config::CostParams;
use crate::device::{DeviceSpec, Location};
use crate::error::{CloneCloudError, Result};
use crate::vfs::SimFs;

/// Fixed artifact shapes (mirror python/compile/model.py).
pub mod shapes {
    pub const CHUNK: usize = 4096;
    pub const SIG_LEN: usize = 16;
    pub const N_SIGS: usize = 128;
    pub const IMG: usize = 64;
    pub const PATCH: usize = 8;
    pub const N_FILTERS: usize = 16;
    pub const N_USERS: usize = 8;
    pub const KDIM: usize = 256;
    pub const N_CATS: usize = 512;
}

/// Backend for the heavy app compute (the L1/L2 artifacts).
///
/// Deliberately NOT `Send`/`Sync`: the PJRT client wrapper holds
/// thread-local handles (`Rc`, raw pointers). Each node — phone or clone —
/// loads its own runtime on its own thread, exactly as each real device
/// loads its own VM + artifacts.
pub trait ComputeBackend {
    /// Scan one chunk against a signature panel. Returns per-signature
    /// match counts and the total.
    fn scan_chunk(&self, chunk: &[f32], sigs: &[f32]) -> Result<(Vec<f32>, f32)>;
    /// Detect faces in one image. Returns (per-filter maxima, per-filter
    /// counts, total faces).
    fn face_detect(&self, img: &[f32], filters: &[f32], thresh: f32)
        -> Result<(Vec<f32>, Vec<f32>, f32)>;
    /// Score user vectors against a category panel. Returns (scores,
    /// best index per user, best score per user).
    fn categorize(&self, users: &[f32], cats: &[f32]) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)>;
    /// Backend name for diagnostics.
    fn name(&self) -> &'static str;
}

/// Pure-Rust reference backend (same math as python/compile/kernels/ref.py).
pub struct RustCompute;

impl ComputeBackend for RustCompute {
    fn scan_chunk(&self, chunk: &[f32], sigs: &[f32]) -> Result<(Vec<f32>, f32)> {
        use shapes::*;
        if chunk.len() != CHUNK || sigs.len() != SIG_LEN * N_SIGS {
            return Err(CloneCloudError::runtime("scan_chunk shape mismatch"));
        }
        let mut counts = vec![0f32; N_SIGS];
        // windows include pad tail of -1 (cannot match byte values).
        for w0 in 0..CHUNK {
            'sig: for s in 0..N_SIGS {
                for k in 0..SIG_LEN {
                    let wv = if w0 + k < CHUNK { chunk[w0 + k] } else { -1.0 };
                    // sigs is (SIG_LEN, N_SIGS) row-major.
                    if (wv - sigs[k * N_SIGS + s]).abs() > 0.25 {
                        continue 'sig;
                    }
                }
                counts[s] += 1.0;
            }
        }
        let total = counts.iter().sum();
        Ok((counts, total))
    }

    fn face_detect(
        &self,
        img: &[f32],
        filters: &[f32],
        thresh: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        use shapes::*;
        if img.len() != IMG * IMG || filters.len() != PATCH * PATCH * N_FILTERS {
            return Err(CloneCloudError::runtime("face_detect shape mismatch"));
        }
        let side = IMG - PATCH + 1;
        let mut maxima = vec![f32::NEG_INFINITY; N_FILTERS];
        let mut counts = vec![0f32; N_FILTERS];
        for r in 0..side {
            for c in 0..side {
                for f in 0..N_FILTERS {
                    let mut resp = 0f32;
                    for dr in 0..PATCH {
                        for dc in 0..PATCH {
                            // filters is (PATCH*PATCH, N_FILTERS) row-major.
                            resp += img[(r + dr) * IMG + c + dc]
                                * filters[(dr * PATCH + dc) * N_FILTERS + f];
                        }
                    }
                    if resp > maxima[f] {
                        maxima[f] = resp;
                    }
                    if resp > thresh {
                        counts[f] += 1.0;
                    }
                }
            }
        }
        let faces = counts.iter().sum();
        Ok((maxima, counts, faces))
    }

    fn categorize(&self, users: &[f32], cats: &[f32]) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)> {
        use shapes::*;
        if users.len() != N_USERS * KDIM || cats.len() != KDIM * N_CATS {
            return Err(CloneCloudError::runtime("categorize shape mismatch"));
        }
        const EPS: f32 = 1e-6;
        let mut cat_norm = vec![0f32; N_CATS];
        for k in 0..KDIM {
            for n in 0..N_CATS {
                let v = cats[k * N_CATS + n];
                cat_norm[n] += v * v;
            }
        }
        for n in cat_norm.iter_mut() {
            *n = n.sqrt() + EPS;
        }
        let mut scores = vec![0f32; N_USERS * N_CATS];
        let mut best = vec![0i32; N_USERS];
        let mut best_score = vec![f32::NEG_INFINITY; N_USERS];
        for u in 0..N_USERS {
            let row = &users[u * KDIM..(u + 1) * KDIM];
            let unorm = row.iter().map(|x| x * x).sum::<f32>().sqrt() + EPS;
            for n in 0..N_CATS {
                let mut dot = 0f32;
                for k in 0..KDIM {
                    dot += row[k] * cats[k * N_CATS + n];
                }
                let s = dot / (unorm * cat_norm[n]);
                scores[u * N_CATS + n] = s;
                if s > best_score[u] {
                    best_score[u] = s;
                    best[u] = n as i32;
                }
            }
        }
        Ok((scores, best, best_score))
    }

    fn name(&self) -> &'static str {
        "rust-reference"
    }
}

/// Per-node environment reachable from native methods: the synchronized
/// file system, sensors/UI (mobile only), and the compute backend.
pub struct NodeEnv {
    pub vfs: SimFs,
    pub compute: Arc<dyn ComputeBackend>,
    /// UI output log (pinned native side effects, visible to tests).
    pub ui_log: Vec<String>,
    /// Count of native invocations by name (metrics).
    pub native_calls: HashMap<String, u64>,
}

/// Hand-rolled: the compute backend is shared (`Arc`), not duplicated —
/// a speculative local fork must race against the same backend the
/// original process uses.
impl Clone for NodeEnv {
    fn clone(&self) -> NodeEnv {
        NodeEnv {
            vfs: self.vfs.clone(),
            compute: Arc::clone(&self.compute),
            ui_log: self.ui_log.clone(),
            native_calls: self.native_calls.clone(),
        }
    }
}

impl NodeEnv {
    pub fn new(vfs: SimFs, compute: Arc<dyn ComputeBackend>) -> NodeEnv {
        NodeEnv {
            vfs,
            compute,
            ui_log: Vec::new(),
            native_calls: HashMap::new(),
        }
    }

    pub fn with_rust_compute(vfs: SimFs) -> NodeEnv {
        NodeEnv::new(vfs, Arc::new(RustCompute))
    }
}

/// Context handed to native handlers.
pub struct NativeCtx<'a> {
    pub heap: &'a mut Heap,
    pub clock: &'a mut VirtualClock,
    pub device: &'a DeviceSpec,
    pub costs: &'a CostParams,
    pub location: Location,
    pub env: &'a mut NodeEnv,
    /// Class id used for arrays allocated by natives.
    pub array_class: super::bytecode::ClassId,
    /// Clone-monolithic / profiling override for Property-1 enforcement.
    pub allow_pinned: bool,
}

type Handler = fn(&mut NativeCtx, &[Value]) -> Result<Value>;

/// A registered native method.
pub struct NativeDef {
    pub name: &'static str,
    /// Property 1: pinned natives form V_M.
    pub pinned: bool,
    pub nargs: usize,
    pub handler: Handler,
}

/// The native registry: a fixed table, stable across processes (both the
/// phone and the clone register the same natives — what differs is only
/// whether the *pinned* ones may legally be reached there).
pub struct NativeRegistry {
    defs: Vec<NativeDef>,
    by_name: HashMap<&'static str, NativeId>,
}

impl NativeRegistry {
    /// The standard DroidVM native set.
    pub fn standard() -> &'static NativeRegistry {
        static REG: std::sync::OnceLock<NativeRegistry> = std::sync::OnceLock::new();
        REG.get_or_init(NativeRegistry::build)
    }

    fn build() -> NativeRegistry {
        let defs: Vec<NativeDef> = vec![
            NativeDef { name: "ui.init", pinned: true, nargs: 0, handler: n_ui_init },
            NativeDef { name: "ui.show", pinned: true, nargs: 1, handler: n_ui_show },
            NativeDef { name: "sensor.gps", pinned: true, nargs: 0, handler: n_sensor_gps },
            NativeDef { name: "fs.count", pinned: false, nargs: 0, handler: n_fs_count },
            NativeDef { name: "fs.size", pinned: false, nargs: 1, handler: n_fs_size },
            NativeDef { name: "fs.read", pinned: false, nargs: 3, handler: n_fs_read },
            NativeDef {
                name: "compute.scan_chunk",
                pinned: false,
                nargs: 2,
                handler: n_scan_chunk,
            },
            NativeDef {
                name: "compute.face_detect",
                pinned: false,
                nargs: 3,
                handler: n_face_detect,
            },
            NativeDef {
                name: "compute.categorize",
                pinned: false,
                nargs: 2,
                handler: n_categorize,
            },
        ];
        let by_name = defs
            .iter()
            .enumerate()
            .map(|(i, d)| (d.name, NativeId(i as u16)))
            .collect();
        NativeRegistry { defs, by_name }
    }

    pub fn lookup(&self, name: &str) -> Option<NativeId> {
        self.by_name.get(name).copied()
    }

    pub fn def(&self, id: NativeId) -> &NativeDef {
        &self.defs[id.0 as usize]
    }

    pub fn len(&self) -> usize {
        self.defs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.defs.is_empty()
    }

    /// Dispatch a native call, recording metrics.
    pub fn call(&self, id: NativeId, ctx: &mut NativeCtx, args: &[Value]) -> Result<Value> {
        let def = self.def(id);
        if args.len() != def.nargs {
            return Err(CloneCloudError::Native {
                name: def.name.into(),
                message: format!("expected {} args, got {}", def.nargs, args.len()),
            });
        }
        if def.pinned && ctx.location != Location::Mobile && !ctx.allow_pinned {
            return Err(CloneCloudError::Native {
                name: def.name.into(),
                message: "pinned native invoked on clone (partitioning violated Property 1)"
                    .into(),
            });
        }
        *ctx.env.native_calls.entry(def.name.to_string()).or_insert(0) += 1;
        (def.handler)(ctx, args)
    }
}

// ------------------------------------------------------------- handlers

fn err(name: &str, msg: impl Into<String>) -> CloneCloudError {
    CloneCloudError::Native {
        name: name.into(),
        message: msg.into(),
    }
}

fn get_bytes<'h>(ctx: &'h NativeCtx, v: &Value, name: &str) -> Result<&'h [u8]> {
    let id = v.as_ref().ok_or_else(|| err(name, "expected byte-array ref"))?;
    match &ctx.heap.get(id)?.body {
        ObjBody::ByteArray(b) => Ok(b),
        _ => Err(err(name, "expected byte array")),
    }
}

fn get_floats<'h>(ctx: &'h NativeCtx, v: &Value, name: &str) -> Result<&'h [f32]> {
    let id = v.as_ref().ok_or_else(|| err(name, "expected float-array ref"))?;
    match &ctx.heap.get(id)?.body {
        ObjBody::FloatArray(f) => Ok(f),
        _ => Err(err(name, "expected float array")),
    }
}

fn n_ui_init(ctx: &mut NativeCtx, _args: &[Value]) -> Result<Value> {
    ctx.clock.charge_us(ctx.device.scale_us(200.0));
    ctx.env.ui_log.push("ui.init".into());
    Ok(Value::Null)
}

fn n_ui_show(ctx: &mut NativeCtx, args: &[Value]) -> Result<Value> {
    ctx.clock.charge_us(ctx.device.scale_us(100.0));
    let text = match args[0] {
        Value::Int(x) => format!("int:{x}"),
        Value::Float(x) => format!("float:{x:.4}"),
        Value::Null => "null".into(),
        Value::Ref(r) => format!("obj:{}", r.0),
    };
    ctx.env.ui_log.push(format!("ui.show {text}"));
    Ok(Value::Null)
}

fn n_sensor_gps(ctx: &mut NativeCtx, _args: &[Value]) -> Result<Value> {
    ctx.clock.charge_us(ctx.device.scale_us(500.0));
    // Berkeley, where the paper was written.
    Ok(Value::Float(37.8716))
}

fn n_fs_count(ctx: &mut NativeCtx, _args: &[Value]) -> Result<Value> {
    ctx.clock.charge_us(ctx.device.scale_us(20.0));
    Ok(Value::Int(ctx.env.vfs.count() as i64))
}

fn n_fs_size(ctx: &mut NativeCtx, args: &[Value]) -> Result<Value> {
    ctx.clock.charge_us(ctx.device.scale_us(20.0));
    let i = args[0].as_int().ok_or_else(|| err("fs.size", "bad index"))? as usize;
    ctx.env
        .vfs
        .size(i)
        .map(|s| Value::Int(s as i64))
        .ok_or_else(|| err("fs.size", format!("no file {i}")))
}

fn n_fs_read(ctx: &mut NativeCtx, args: &[Value]) -> Result<Value> {
    let i = args[0].as_int().ok_or_else(|| err("fs.read", "bad index"))? as usize;
    let off = args[1].as_int().ok_or_else(|| err("fs.read", "bad offset"))? as usize;
    let len = args[2].as_int().ok_or_else(|| err("fs.read", "bad len"))? as usize;
    let data = ctx
        .env
        .vfs
        .read(i, off, len)
        .ok_or_else(|| err("fs.read", format!("no file {i}")))?
        .to_vec();
    // I/O cost: flash-read latency + per-byte.
    ctx.clock
        .charge_us(ctx.device.scale_us(50.0 + 0.002 * data.len() as f64));
    let id = ctx.heap.alloc_byte_array(ctx.array_class, data);
    Ok(Value::Ref(id))
}

fn n_scan_chunk(ctx: &mut NativeCtx, args: &[Value]) -> Result<Value> {
    let name = "compute.scan_chunk";
    let chunk_bytes = get_bytes(ctx, &args[0], name)?;
    if chunk_bytes.len() > shapes::CHUNK {
        return Err(err(name, "chunk too large"));
    }
    // Pad to artifact shape with -1 (never matches a byte).
    let mut chunk = vec![-1.0f32; shapes::CHUNK];
    for (i, &b) in chunk_bytes.iter().enumerate() {
        chunk[i] = b as f32;
    }
    let sigs = get_floats(ctx, &args[1], name)?.to_vec();
    let (_counts, total) = ctx.env.compute.scan_chunk(&chunk, &sigs)?;
    ctx.clock
        .charge_us(ctx.device.scale_us(ctx.costs.scan_chunk_us));
    Ok(Value::Int(total as i64))
}

fn n_face_detect(ctx: &mut NativeCtx, args: &[Value]) -> Result<Value> {
    let name = "compute.face_detect";
    let img_bytes = get_bytes(ctx, &args[0], name)?;
    if img_bytes.len() != shapes::IMG * shapes::IMG {
        return Err(err(name, format!("image must be {0}x{0}", shapes::IMG)));
    }
    let img: Vec<f32> = img_bytes.iter().map(|&b| b as f32 / 255.0).collect();
    let filters = get_floats(ctx, &args[1], name)?.to_vec();
    let thresh = args[2]
        .as_float()
        .ok_or_else(|| err(name, "bad threshold"))? as f32;
    let (_maxima, _counts, faces) = ctx.env.compute.face_detect(&img, &filters, thresh)?;
    ctx.clock
        .charge_us(ctx.device.scale_us(ctx.costs.face_detect_us));
    Ok(Value::Int(faces as i64))
}

fn n_categorize(ctx: &mut NativeCtx, args: &[Value]) -> Result<Value> {
    let name = "compute.categorize";
    let users = get_floats(ctx, &args[0], name)?.to_vec();
    let cats = get_floats(ctx, &args[1], name)?.to_vec();
    let (_scores, best, best_score) = ctx.env.compute.categorize(&users, &cats)?;
    ctx.clock
        .charge_us(ctx.device.scale_us(ctx.costs.categorize_us));
    // Result object: per-user best scores, with best[0] index encoded in
    // the app-visible return (float array [best0, score0, score1, ...]).
    let mut out = Vec::with_capacity(1 + best_score.len());
    out.push(best[0] as f32);
    out.extend_from_slice(&best_score);
    let id = ctx.heap.alloc_float_array(ctx.array_class, out);
    Ok(Value::Ref(id))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::bytecode::ClassId;

    fn ctx_parts() -> (Heap, VirtualClock, DeviceSpec, CostParams, NodeEnv) {
        (
            Heap::new(),
            VirtualClock::new(),
            DeviceSpec::clone_desktop(),
            CostParams::default(),
            NodeEnv::with_rust_compute(SimFs::new()),
        )
    }

    macro_rules! ctx {
        ($h:ident, $c:ident, $d:ident, $costs:ident, $e:ident) => {
            NativeCtx {
                heap: &mut $h,
                clock: &mut $c,
                device: &$d,
                costs: &$costs,
                location: Location::Mobile,
                env: &mut $e,
                array_class: ClassId(0),
                allow_pinned: false,
            }
        };
    }

    #[test]
    fn registry_lookup_and_arity() {
        let reg = NativeRegistry::standard();
        assert!(reg.lookup("fs.read").is_some());
        assert!(reg.lookup("nope").is_none());
        let (mut h, mut c, d, costs, mut e) = ctx_parts();
        let mut cx = ctx!(h, c, d, costs, e);
        let id = reg.lookup("fs.count").unwrap();
        // Wrong arity.
        assert!(reg.call(id, &mut cx, &[Value::Int(1)]).is_err());
    }

    #[test]
    fn pinned_native_rejected_on_clone() {
        let reg = NativeRegistry::standard();
        let (mut h, mut c, d, costs, mut e) = ctx_parts();
        let mut cx = ctx!(h, c, d, costs, e);
        cx.location = Location::Clone;
        let id = reg.lookup("ui.init").unwrap();
        let r = reg.call(id, &mut cx, &[]);
        assert!(r.is_err(), "Property 1 enforced at runtime");
        cx.location = Location::Mobile;
        assert!(reg.call(id, &mut cx, &[]).is_ok());
    }

    #[test]
    fn fs_read_allocates_byte_array_and_charges_time() {
        let reg = NativeRegistry::standard();
        let (mut h, mut c, d, costs, mut e) = ctx_parts();
        e.vfs.add("f", vec![9, 8, 7, 6]);
        let mut cx = ctx!(h, c, d, costs, e);
        let id = reg.lookup("fs.read").unwrap();
        let v = reg
            .call(id, &mut cx, &[Value::Int(0), Value::Int(1), Value::Int(2)])
            .unwrap();
        let oid = v.as_ref().unwrap();
        match &cx.heap.get(oid).unwrap().body {
            ObjBody::ByteArray(b) => assert_eq!(b, &vec![8, 7]),
            _ => panic!("expected byte array"),
        }
        assert!(cx.clock.now_us() > 0.0);
    }

    #[test]
    fn rust_compute_scan_finds_planted_sig() {
        let b = RustCompute;
        let mut sigs = vec![0f32; shapes::SIG_LEN * shapes::N_SIGS];
        // Signature 5: bytes 1..=16.
        for k in 0..shapes::SIG_LEN {
            sigs[k * shapes::N_SIGS + 5] = (k + 1) as f32;
        }
        let mut chunk = vec![300.0f32; shapes::CHUNK];
        for k in 0..shapes::SIG_LEN {
            chunk[100 + k] = (k + 1) as f32;
        }
        let (counts, total) = b.scan_chunk(&chunk, &sigs).unwrap();
        assert_eq!(total, 1.0);
        assert_eq!(counts[5], 1.0);
    }

    #[test]
    fn rust_compute_categorize_identical_vector_wins() {
        let b = RustCompute;
        let mut cats = vec![0f32; shapes::KDIM * shapes::N_CATS];
        let mut rng = crate::util::rng::Rng::new(4);
        for v in cats.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let mut users = vec![0f32; shapes::N_USERS * shapes::KDIM];
        for u in 0..shapes::N_USERS {
            for k in 0..shapes::KDIM {
                users[u * shapes::KDIM + k] = cats[k * shapes::N_CATS + 37];
            }
        }
        let (_s, best, best_score) = b.categorize(&users, &cats).unwrap();
        assert!(best.iter().all(|&x| x == 37));
        assert!(best_score.iter().all(|&s| (s - 1.0).abs() < 1e-4));
    }

    #[test]
    fn rust_compute_face_detect_planted() {
        let b = RustCompute;
        let mut filters = vec![0f32; 64 * shapes::N_FILTERS];
        let mut rng = crate::util::rng::Rng::new(5);
        for f in 0..shapes::N_FILTERS {
            let mut mean = 0.0;
            let mut col = vec![0f32; 64];
            for item in col.iter_mut() {
                *item = rng.range_f32(-1.0, 1.0);
                mean += *item;
            }
            mean /= 64.0;
            for (k, item) in col.iter().enumerate() {
                filters[k * shapes::N_FILTERS + f] = item - mean;
            }
        }
        let mut img = vec![0f32; shapes::IMG * shapes::IMG];
        // Plant filter 2's pattern at (10, 10), amplified.
        let mut self_dot = 0.0f32;
        for dr in 0..8 {
            for dc in 0..8 {
                let w = filters[(dr * 8 + dc) * shapes::N_FILTERS + 2];
                img[(10 + dr) * shapes::IMG + 10 + dc] = 3.0 * w;
                self_dot += 3.0 * w * w;
            }
        }
        let (maxima, counts, faces) = b.face_detect(&img, &filters, self_dot * 0.9).unwrap();
        assert!(faces >= 1.0);
        assert!(counts[2] >= 1.0);
        assert!((maxima[2] - self_dot).abs() < 1e-3);
    }
}
