//! A DroidVM process: heap + statics + threads + environment.
//!
//! Processes are forked from the Zygote template (paper §4.3): the warm
//! system heap is copied in, then the app's `main` thread is spawned.
//! The process also carries the virtual clock and the device spec it is
//! executing on, so interpreted and native work charge the right costs.

use std::sync::Arc;

use super::bytecode::{ClassId, MRef};
use super::class::Program;
use super::heap::Heap;
use super::natives::NodeEnv;
use super::thread::{Frame, ThreadStatus, VmThread};
use super::value::Value;
use crate::clock::VirtualClock;
use crate::config::CostParams;
use crate::device::{DeviceSpec, Location};
use crate::error::{CloneCloudError, Result};

/// Runtime counters for one process.
#[derive(Debug, Clone, Default)]
pub struct VmMetrics {
    pub instrs: u64,
    pub invokes: u64,
    pub native_calls: u64,
    pub allocations: u64,
}

/// One running VM process.
///
/// `Clone` is a full fork: heap, statics, threads, clock, and (shared
/// compute backend aside) environment. The exec driver's speculative
/// local-vs-clone race runs the local leg on a fork so the loser can be
/// discarded atomically.
#[derive(Clone)]
pub struct Process {
    pub program: Arc<Program>,
    pub heap: Heap,
    /// Static fields, indexed [class][static-slot]. Mutations must go
    /// through [`Process::put_static`] (the statics write barrier) so
    /// delta captures can tell which slots changed; direct writes are
    /// reserved for pre-session setup (app builders, tests).
    pub statics: Vec<Vec<Value>>,
    /// Mutation epoch of each static slot, same shape as `statics` —
    /// the statics twin of `Object::epoch` (see `Heap::get_mut`).
    pub statics_epoch: Vec<Vec<u64>>,
    pub threads: Vec<VmThread>,
    pub clock: VirtualClock,
    pub device: DeviceSpec,
    pub location: Location,
    pub env: NodeEnv,
    pub metrics: VmMetrics,
    /// Class used for arrays allocated by natives and `NewArray`.
    pub array_class: ClassId,
    /// Cost calibration override; `None` uses `CostParams::default()`.
    pub cost_params: Option<CostParams>,
    /// Allow pinned (V_M) natives to run on the clone. Used for the
    /// clone-monolithic baseline ("execution at the clone alone",
    /// Table 1 col. 4) and for clone-side profiling runs — the paper's
    /// clone is a full Android image where UI/sensor calls exist.
    pub allow_pinned: bool,
}

impl Process {
    /// Create a process with an empty heap (no Zygote warmup).
    pub fn new(
        program: Arc<Program>,
        device: DeviceSpec,
        location: Location,
        env: NodeEnv,
    ) -> Process {
        let statics: Vec<Vec<Value>> = program
            .classes
            .iter()
            .map(|c| vec![Value::Null; c.statics.len()])
            .collect();
        let statics_epoch = statics.iter().map(|s| vec![0u64; s.len()]).collect();
        // Array class: a system class named "[arr]" if present, else 0.
        let array_class = program.class_id("[arr]").unwrap_or(ClassId(0));
        Process {
            program,
            heap: Heap::new(),
            statics,
            statics_epoch,
            threads: Vec::new(),
            clock: VirtualClock::new(),
            device,
            location,
            env,
            metrics: VmMetrics::default(),
            array_class,
            cost_params: None,
            allow_pinned: false,
        }
    }

    /// Fork from a Zygote template heap (copy-on-fork semantics: the
    /// template objects arrive clean, with their (class, seq) names).
    pub fn fork_from_zygote(
        program: Arc<Program>,
        zygote_heap: &Heap,
        device: DeviceSpec,
        location: Location,
        env: NodeEnv,
    ) -> Process {
        let mut p = Process::new(program, device, location, env);
        p.heap = zygote_heap.clone();
        p
    }

    /// Spawn a thread entering `mref` with the given arguments.
    pub fn spawn_thread(&mut self, mref: MRef, args: &[Value]) -> Result<u32> {
        let m = self.program.method(mref);
        if m.is_native() {
            return Err(CloneCloudError::vm("cannot spawn a thread on a native method"));
        }
        if args.len() != m.nargs {
            return Err(CloneCloudError::vm(format!(
                "{} expects {} args, got {}",
                self.program.method_name(mref),
                m.nargs,
                args.len()
            )));
        }
        let mut frame = Frame::new(mref, m.nregs, None);
        frame.regs[..args.len()].copy_from_slice(args);
        let id = self.threads.len() as u32;
        let mut t = VmThread::new(id);
        t.frames.push(frame);
        self.threads.push(t);
        Ok(id)
    }

    pub fn thread(&self, tid: u32) -> Result<&VmThread> {
        self.threads
            .get(tid as usize)
            .ok_or_else(|| CloneCloudError::vm(format!("no thread {tid}")))
    }

    pub fn thread_mut(&mut self, tid: u32) -> Result<&mut VmThread> {
        self.threads
            .get_mut(tid as usize)
            .ok_or_else(|| CloneCloudError::vm(format!("no thread {tid}")))
    }

    /// Store a static field through the write barrier: the slot is
    /// stamped with the current mutation epoch, so delta captures ship
    /// only statics written since the last migration sync point (the
    /// statics leg of the epoch-coherence invariant).
    pub fn put_static(&mut self, class: usize, idx: usize, v: Value) -> Result<()> {
        let epoch = self.heap.epoch();
        let slot = self
            .statics
            .get_mut(class)
            .and_then(|s| s.get_mut(idx))
            .ok_or_else(|| CloneCloudError::vm("static index out of range"))?;
        *slot = v;
        self.statics_epoch[class][idx] = epoch;
        Ok(())
    }

    /// Reset every app-class static to Null, stamping the current epoch.
    /// A *full* capture implies nulls instead of shipping them, so the
    /// receiver must clear stale values before applying the packet's
    /// statics — otherwise a slot reused across sessions could keep a
    /// value the sender has since nulled.
    pub fn reset_app_statics(&mut self) {
        let epoch = self.heap.epoch();
        for (ci, class_statics) in self.statics.iter_mut().enumerate() {
            if self.program.classes[ci].system {
                continue;
            }
            for (i, v) in class_statics.iter_mut().enumerate() {
                *v = Value::Null;
                self.statics_epoch[ci][i] = epoch;
            }
        }
    }

    /// GC roots: all thread frames plus all static fields.
    pub fn gc_roots(&self) -> Vec<super::value::ObjId> {
        let mut roots = Vec::new();
        for t in &self.threads {
            if t.status != ThreadStatus::Finished {
                roots.extend(t.roots());
            }
        }
        for class_statics in &self.statics {
            roots.extend(class_statics.iter().filter_map(|v| v.as_ref()));
        }
        roots
    }

    /// Run a garbage collection; returns objects collected.
    pub fn gc(&mut self) -> usize {
        let roots = self.gc_roots();
        self.heap.gc(&roots)
    }

    /// The process's current mutation epoch (see `Heap::epoch`).
    pub fn current_epoch(&self) -> u64 {
        self.heap.epoch()
    }

    /// Advance the mutation epoch. The migrator calls this at each
    /// migration sync point so subsequent writes are distinguishable from
    /// state the peer already holds (delta migration).
    pub fn advance_epoch(&mut self) -> u64 {
        self.heap.advance_epoch()
    }

    /// Suspend all threads except `except` at their next safe point (the
    /// paper's migrator waits for this before capturing, §5). In this
    /// single-threaded-interpreter model the others are already at
    /// instruction boundaries, so the suspension takes effect now.
    pub fn suspend_others(&mut self, except: u32) {
        for t in &mut self.threads {
            if t.id != except && t.status == ThreadStatus::Runnable {
                t.request_suspend();
                t.status = ThreadStatus::Suspended;
            }
        }
    }

    pub fn resume_others(&mut self, except: u32) {
        for t in &mut self.threads {
            if t.id != except {
                t.resume();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::bytecode::Instr;
    use crate::appvm::class::{ClassDef, MethodDef};
    use crate::vfs::SimFs;

    fn program() -> Arc<Program> {
        let mut p = Program::new();
        let mut c = ClassDef::new("App", false);
        c.add_static("s");
        c.add_method(MethodDef {
            name: "main".into(),
            nargs: 1,
            nregs: 3,
            code: vec![Instr::Return(None)],
            native: None,
            pinned: true,
            native_state: false,
            migration_point: None,
        });
        p.add_class(c);
        p.into_shared()
    }

    fn process() -> Process {
        Process::new(
            program(),
            DeviceSpec::phone_g1(),
            Location::Mobile,
            NodeEnv::with_rust_compute(SimFs::new()),
        )
    }

    #[test]
    fn spawn_validates_args() {
        let mut p = process();
        let main = p.program.entry().unwrap();
        assert!(p.spawn_thread(main, &[]).is_err(), "wrong arity");
        let tid = p.spawn_thread(main, &[Value::Int(1)]).unwrap();
        assert_eq!(tid, 0);
        assert_eq!(p.thread(0).unwrap().depth(), 1);
    }

    #[test]
    fn fork_copies_zygote_heap() {
        let mut zh = Heap::new();
        for _ in 0..10 {
            zh.alloc_zygote(crate::appvm::value::Object::new_fields(ClassId(0), 2));
        }
        let p = Process::fork_from_zygote(
            program(),
            &zh,
            DeviceSpec::clone_desktop(),
            Location::Clone,
            NodeEnv::with_rust_compute(SimFs::new()),
        );
        assert_eq!(p.heap.len(), 10);
    }

    #[test]
    fn suspend_others_skips_self() {
        let mut p = process();
        let main = p.program.entry().unwrap();
        p.spawn_thread(main, &[Value::Int(0)]).unwrap();
        p.spawn_thread(main, &[Value::Int(0)]).unwrap();
        p.suspend_others(0);
        assert_eq!(p.thread(0).unwrap().status, ThreadStatus::Runnable);
        assert_eq!(p.thread(1).unwrap().status, ThreadStatus::Suspended);
        p.resume_others(0);
        assert_eq!(p.thread(1).unwrap().status, ThreadStatus::Runnable);
    }

    #[test]
    fn put_static_stamps_the_mutation_epoch() {
        let mut p = process();
        assert_eq!(p.statics_epoch[0][0], 0);
        p.advance_epoch();
        p.advance_epoch();
        p.put_static(0, 0, Value::Int(9)).unwrap();
        assert_eq!(p.statics[0][0], Value::Int(9));
        assert_eq!(p.statics_epoch[0][0], 2, "barrier stamped the epoch");
        assert!(p.put_static(0, 99, Value::Null).is_err(), "bounds checked");

        p.advance_epoch();
        p.reset_app_statics();
        assert_eq!(p.statics[0][0], Value::Null);
        assert_eq!(p.statics_epoch[0][0], 3, "reset stamps too");
    }

    #[test]
    fn gc_roots_include_statics() {
        let mut p = process();
        let obj = p.heap.alloc(crate::appvm::value::Object::new_fields(ClassId(0), 0));
        p.statics[0][0] = Value::Ref(obj);
        assert!(p.gc_roots().contains(&obj));
        assert_eq!(p.gc(), 0, "static-rooted object survives");
        p.statics[0][0] = Value::Null;
        assert_eq!(p.gc(), 1);
    }
}
