//! Shared single-step instruction semantics.
//!
//! Exactly one implementation of "execute one DroidVM instruction" lives
//! here, used by both execution tiers: the switch-dispatch interpreter
//! (`interp::run_thread`, tier 0) drives it in a loop, and the
//! direct-threaded tier (`tier1`) bails to it for every heavy
//! instruction (invoke/return/allocation/statics stores/`CcStart`/
//! `CcStop`) and for cold code. Anything this function does — charge
//! order, write-barrier routing, error strings, pc adjustment on fault —
//! *is* the VM's semantics; the tiers may only change dispatch speed.
//!
//! The fetch path deliberately avoids the two classic interpreter-loop
//! taxes: the instruction is borrowed from the caller-held `Program`
//! (no per-fetch `Instr` clone — `Invoke` carries a `Vec<Reg>`), and the
//! status/frame/charge bookkeeping runs under a single thread lookup
//! with split field borrows instead of three `thread(tid)` round-trips.

use super::bytecode::{eval_cmp_f, eval_cmp_i, eval_float, eval_int, ArrKind, CmpOp, Instr};
use super::class::Program;
use super::interp::{ExecHooks, RunExit};
use super::natives::{NativeCtx, NativeRegistry};
use super::process::Process;
use super::thread::{Frame, ThreadStatus};
use super::value::{ObjBody, ObjId, Object, Value};
use crate::config::CostParams;
use crate::error::{CloneCloudError, Result};

/// Execute exactly one instruction of thread `tid`: fetch, charge,
/// advance, execute. Returns `Ok(Some(exit))` when the thread reaches an
/// exit condition (completion or a partition point), `Ok(None)` when it
/// merely advanced. `program` must be the process's own program (callers
/// clone the `Arc` once per run so the fetch can borrow instructions
/// while the process is mutated).
pub(crate) fn step_one<H: ExecHooks>(
    p: &mut Process,
    program: &Program,
    tid: u32,
    hooks: &mut H,
    costs: &CostParams,
    instr_cost: f64,
) -> Result<Option<RunExit>> {
    let (instr, mref) = {
        let Process {
            ref mut threads,
            ref mut clock,
            ref mut metrics,
            ..
        } = *p;
        let t = threads
            .get_mut(tid as usize)
            .ok_or_else(|| CloneCloudError::vm(format!("no thread {tid}")))?;
        match t.status {
            ThreadStatus::Finished => return Ok(Some(RunExit::Completed(None))),
            ThreadStatus::Suspended | ThreadStatus::Migrated => {
                return Err(CloneCloudError::vm(format!(
                    "thread {tid} not runnable ({:?})",
                    t.status
                )))
            }
            ThreadStatus::Runnable => {}
        }

        // Fetch.
        let frame = t
            .frames
            .last_mut()
            .ok_or_else(|| CloneCloudError::vm("runnable thread with no frames"))?;
        let mref = frame.method;
        let pc = frame.pc;
        let method = program.method(mref);
        if pc >= method.code.len() {
            return Err(CloneCloudError::vm(format!(
                "pc {pc} past end of {}",
                program.method_name(mref)
            )));
        }

        // Charge and advance.
        clock.charge_us(instr_cost);
        metrics.instrs += 1;
        t.cpu_us += instr_cost;
        frame.pc = pc + 1;
        (&method.code[pc], mref)
    };

    // Execute.
    match instr {
        Instr::Nop => {}
        Instr::Const(d, v) => set_reg(p, tid, *d, Value::Int(*v))?,
        Instr::ConstF(d, v) => set_reg(p, tid, *d, Value::Float(*v))?,
        Instr::Move(d, s) => {
            let v = get_reg(p, tid, *s)?;
            set_reg(p, tid, *d, v)?;
        }
        Instr::IntBin(op, d, a, b) => {
            let (x, y) = (int_reg(p, tid, *a)?, int_reg(p, tid, *b)?);
            let v =
                eval_int(*op, x, y).ok_or_else(|| CloneCloudError::vm("division by zero"))?;
            set_reg(p, tid, *d, Value::Int(v))?;
        }
        Instr::FloatBin(op, d, a, b) => {
            let (x, y) = (float_reg(p, tid, *a)?, float_reg(p, tid, *b)?);
            set_reg(p, tid, *d, Value::Float(eval_float(*op, x, y)))?;
        }
        Instr::Cmp(op, d, a, b) => {
            let va = get_reg(p, tid, *a)?;
            let vb = get_reg(p, tid, *b)?;
            let r = cmp_values(*op, va, vb)?;
            set_reg(p, tid, *d, Value::Int(r as i64))?;
        }
        Instr::IfZ(r, target) => {
            if !get_reg(p, tid, *r)?.is_truthy() {
                jump(p, tid, *target)?;
            }
        }
        Instr::IfNZ(r, target) => {
            if get_reg(p, tid, *r)?.is_truthy() {
                jump(p, tid, *target)?;
            }
        }
        Instr::IfCmp(op, a, b, target) => {
            let va = get_reg(p, tid, *a)?;
            let vb = get_reg(p, tid, *b)?;
            if cmp_values(*op, va, vb)? {
                jump(p, tid, *target)?;
            }
        }
        Instr::Goto(target) => jump(p, tid, *target)?,
        Instr::Invoke { mref: callee, ret, args } => {
            let callee = *callee;
            p.metrics.invokes += 1;
            let callee_def = program.method(callee);
            let nargs = callee_def.nargs;
            if args.len() != nargs {
                return Err(CloneCloudError::vm(format!(
                    "{} expects {nargs} args, got {}",
                    program.method_name(callee),
                    args.len()
                )));
            }
            let mut argv = Vec::with_capacity(args.len());
            for &r in args {
                argv.push(get_reg(p, tid, r)?);
            }
            if let Some(nid) = callee_def.native {
                // Natives execute inline (treated as part of the
                // calling method's body by the profiler, §3.2).
                p.metrics.native_calls += 1;
                let reg = NativeRegistry::standard();
                let result = {
                    let Process {
                        ref mut heap,
                        ref mut clock,
                        ref device,
                        location,
                        ref mut env,
                        array_class,
                        allow_pinned,
                        ..
                    } = *p;
                    let mut ctx = NativeCtx {
                        heap,
                        clock,
                        device,
                        costs,
                        location,
                        env,
                        array_class,
                        allow_pinned,
                    };
                    reg.call(nid, &mut ctx, &argv)?
                };
                if let Some(d) = ret {
                    set_reg(p, tid, *d, result)?;
                }
                hooks.on_native(p, tid, mref, callee);
            } else {
                let nregs = callee_def.nregs;
                let mut frame = Frame::new(callee, nregs, *ret);
                frame.regs[..argv.len()].copy_from_slice(&argv);
                p.thread_mut(tid)?.frames.push(frame);
                hooks.on_entry(p, tid, callee);
            }
        }
        Instr::Return(src) => {
            let rv = match src {
                Some(r) => Some(get_reg(p, tid, *r)?),
                None => None,
            };
            let finished_frame = p
                .thread_mut(tid)?
                .frames
                .pop()
                .ok_or_else(|| CloneCloudError::vm("return with no frame"))?;
            hooks.on_exit(p, tid, finished_frame.method);
            let t = p.thread_mut(tid)?;
            if t.frames.is_empty() {
                t.status = ThreadStatus::Finished;
                return Ok(Some(RunExit::Completed(rv)));
            }
            if let (Some(dst), Some(v)) = (finished_frame.ret_reg, rv) {
                set_reg(p, tid, dst, v)?;
            }
        }
        Instr::New(d, class) => {
            let nfields = program.class(*class).fields.len();
            p.metrics.allocations += 1;
            let id = p.heap.alloc(Object::new_fields(*class, nfields));
            set_reg(p, tid, *d, Value::Ref(id))?;
        }
        Instr::GetField(d, o, idx) => {
            let oid = ref_reg(p, tid, *o)?;
            let obj = p.heap.get(oid)?;
            let v = match &obj.body {
                ObjBody::Fields(fs) => *fs.get(*idx as usize).ok_or_else(|| {
                    CloneCloudError::vm(format!("field index {idx} out of range"))
                })?,
                _ => return Err(CloneCloudError::vm("getfield on array")),
            };
            set_reg(p, tid, *d, v)?;
        }
        Instr::PutField(o, idx, s) => {
            let v = get_reg(p, tid, *s)?;
            let oid = ref_reg(p, tid, *o)?;
            let obj = p.heap.get_mut(oid)?;
            match &mut obj.body {
                ObjBody::Fields(fs) => {
                    let slot = fs.get_mut(*idx as usize).ok_or_else(|| {
                        CloneCloudError::vm(format!("field index {idx} out of range"))
                    })?;
                    *slot = v;
                }
                _ => return Err(CloneCloudError::vm("putfield on array")),
            }
        }
        Instr::GetStatic(d, class, idx) => {
            let v = *p
                .statics
                .get(class.0 as usize)
                .and_then(|s| s.get(*idx as usize))
                .ok_or_else(|| CloneCloudError::vm("static index out of range"))?;
            set_reg(p, tid, *d, v)?;
        }
        Instr::PutStatic(class, idx, s) => {
            let v = get_reg(p, tid, *s)?;
            // Through the statics write barrier: stamps the slot's
            // mutation epoch for delta captures.
            p.put_static(class.0 as usize, *idx as usize, v)?;
        }
        Instr::NewArray(d, kind, len_reg) => {
            let len = int_reg(p, tid, *len_reg)?;
            if len < 0 {
                return Err(CloneCloudError::vm("negative array length"));
            }
            p.metrics.allocations += 1;
            let class = p.array_class;
            let id = match kind {
                ArrKind::Byte => p.heap.alloc_byte_array(class, vec![0; len as usize]),
                ArrKind::Float => p.heap.alloc_float_array(class, vec![0.0; len as usize]),
                ArrKind::Val => p.heap.alloc_ref_array(class, len as usize),
            };
            set_reg(p, tid, *d, Value::Ref(id))?;
        }
        Instr::ArrGet(d, arr, idx) => {
            let oid = ref_reg(p, tid, *arr)?;
            let i = int_reg(p, tid, *idx)? as usize;
            let v = match &p.heap.get(oid)?.body {
                ObjBody::ByteArray(b) => Value::Int(*b.get(i).ok_or_else(oob)? as i64),
                ObjBody::FloatArray(f) => Value::Float(*f.get(i).ok_or_else(oob)? as f64),
                ObjBody::RefArray(v) => *v.get(i).ok_or_else(oob)?,
                ObjBody::Fields(_) => return Err(CloneCloudError::vm("arrget on object")),
            };
            set_reg(p, tid, *d, v)?;
        }
        Instr::ArrPut(arr, idx, src) => {
            let v = get_reg(p, tid, *src)?;
            let oid = ref_reg(p, tid, *arr)?;
            let i = int_reg(p, tid, *idx)? as usize;
            match &mut p.heap.get_mut(oid)?.body {
                ObjBody::ByteArray(b) => {
                    let slot = b.get_mut(i).ok_or_else(oob)?;
                    *slot = v
                        .as_int()
                        .ok_or_else(|| CloneCloudError::vm("byte array stores require ints"))?
                        as u8;
                }
                ObjBody::FloatArray(f) => {
                    let slot = f.get_mut(i).ok_or_else(oob)?;
                    *slot = v.as_float().ok_or_else(|| {
                        CloneCloudError::vm("float array stores require numbers")
                    })? as f32;
                }
                ObjBody::RefArray(rv) => {
                    let slot = rv.get_mut(i).ok_or_else(oob)?;
                    *slot = v;
                }
                ObjBody::Fields(_) => return Err(CloneCloudError::vm("arrput on object")),
            }
        }
        Instr::ArrLen(d, arr) => {
            let oid = ref_reg(p, tid, *arr)?;
            let len = match &p.heap.get(oid)?.body {
                ObjBody::ByteArray(b) => b.len(),
                ObjBody::FloatArray(f) => f.len(),
                ObjBody::RefArray(v) => v.len(),
                ObjBody::Fields(_) => return Err(CloneCloudError::vm("arrlen on object")),
            };
            set_reg(p, tid, *d, Value::Int(len as i64))?;
        }
        Instr::IntToFloat(d, s) => {
            let v = int_reg(p, tid, *s)?;
            set_reg(p, tid, *d, Value::Float(v as f64))?;
        }
        Instr::FloatToInt(d, s) => {
            let v = float_reg(p, tid, *s)?;
            set_reg(p, tid, *d, Value::Int(v as i64))?;
        }
        Instr::CcStart(point) => {
            return Ok(Some(RunExit::MigrationPoint { point: *point }));
        }
        Instr::CcStop(point) => {
            return Ok(Some(RunExit::ReintegrationPoint { point: *point }));
        }
    }
    Ok(None)
}

pub(crate) fn oob() -> CloneCloudError {
    CloneCloudError::vm("array index out of bounds")
}

pub(crate) fn cmp_values(op: CmpOp, a: Value, b: Value) -> Result<bool> {
    match (a, b) {
        (Value::Int(x), Value::Int(y)) => Ok(eval_cmp_i(op, x, y)),
        (Value::Null, Value::Null) => Ok(eval_cmp_i(op, 0, 0)),
        (Value::Ref(x), Value::Ref(y)) => Ok(eval_cmp_i(op, x.0 as i64, y.0 as i64)),
        (Value::Ref(_), Value::Null) => Ok(eval_cmp_i(op, 1, 0)),
        (Value::Null, Value::Ref(_)) => Ok(eval_cmp_i(op, 0, 1)),
        _ => {
            let x = a
                .as_float()
                .ok_or_else(|| CloneCloudError::vm("uncomparable values"))?;
            let y = b
                .as_float()
                .ok_or_else(|| CloneCloudError::vm("uncomparable values"))?;
            Ok(eval_cmp_f(op, x, y))
        }
    }
}

fn get_reg(p: &Process, tid: u32, r: u8) -> Result<Value> {
    let f = p
        .thread(tid)?
        .current_frame()
        .ok_or_else(|| CloneCloudError::vm("no frame"))?;
    f.regs
        .get(r as usize)
        .copied()
        .ok_or_else(|| CloneCloudError::vm(format!("register r{r} out of range")))
}

fn set_reg(p: &mut Process, tid: u32, r: u8, v: Value) -> Result<()> {
    let f = p
        .thread_mut(tid)?
        .current_frame_mut()
        .ok_or_else(|| CloneCloudError::vm("no frame"))?;
    let slot = f
        .regs
        .get_mut(r as usize)
        .ok_or_else(|| CloneCloudError::vm(format!("register r{r} out of range")))?;
    *slot = v;
    Ok(())
}

fn int_reg(p: &Process, tid: u32, r: u8) -> Result<i64> {
    get_reg(p, tid, r)?
        .as_int()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not an int")))
}

fn float_reg(p: &Process, tid: u32, r: u8) -> Result<f64> {
    get_reg(p, tid, r)?
        .as_float()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not a float")))
}

fn ref_reg(p: &Process, tid: u32, r: u8) -> Result<ObjId> {
    get_reg(p, tid, r)?
        .as_ref()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not a reference (null deref?)")))
}

fn jump(p: &mut Process, tid: u32, target: u32) -> Result<()> {
    let f = p
        .thread_mut(tid)?
        .current_frame_mut()
        .ok_or_else(|| CloneCloudError::vm("no frame"))?;
    f.pc = target as usize;
    Ok(())
}
