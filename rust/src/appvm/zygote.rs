//! The Zygote template process (paper §4.3).
//!
//! Android forks every app process from a warm "Zygote" template whose
//! heap already holds ~40,000 system objects. Because an identical
//! template boots independently on the phone and on the clone, CloneCloud
//! can avoid transmitting any Zygote object that is still clean, naming
//! objects by (class name, construction sequence) — an assumption the
//! paper verified holds across Zygote instances.
//!
//! This module builds a deterministic template heap: same program + same
//! parameters ⇒ byte-identical object population and identical
//! (class, seq) names on both devices, independently constructed.

use std::sync::Arc;

use super::bytecode::ClassId;
use super::class::{ClassDef, Program};
use super::heap::Heap;
use super::process::Process;
use super::value::{ObjBody, ObjId, Object, Value};
use crate::util::rng::Rng;

/// Names of the synthetic system classes warmed in the template.
pub const ZYGOTE_CLASSES: &[&str] = &[
    "sys.String",
    "sys.HashMapEntry",
    "sys.Resource",
    "sys.WidgetStyle",
    "sys.FontGlyph",
];

/// Add the Zygote system classes (and the array class) to a program.
/// Idempotent: skips classes that already exist.
pub fn install_system_classes(program: &mut Program) {
    if program.class_id("[arr]").is_none() {
        program.add_class(ClassDef::new("[arr]", true));
    }
    for name in ZYGOTE_CLASSES {
        if program.class_id(name).is_none() {
            let mut c = ClassDef::new(name, true);
            c.add_field("a");
            c.add_field("b");
            program.add_class(c);
        }
    }
}

/// Build the template heap with `n_objects` system objects. Construction
/// order is deterministic in (program, n_objects, seed), so two Zygotes
/// booted with the same parameters produce identical (class, seq) names —
/// the §4.3 assumption, which `tests` verify.
pub fn build_template(program: &Arc<Program>, n_objects: usize, seed: u64) -> Heap {
    let mut heap = Heap::new();
    let mut rng = Rng::new(seed);
    let class_ids: Vec<ClassId> = ZYGOTE_CLASSES
        .iter()
        .map(|n| program.class_id(n).expect("system classes installed"))
        .collect();
    let mut prev: Option<Value> = None;
    for i in 0..n_objects {
        let class = class_ids[i % class_ids.len()];
        // Small payloads: a couple of fields, sometimes chaining to the
        // previous object so the template has realistic reference
        // structure for capture traversals.
        let chain = if rng.chance(0.3) {
            prev.unwrap_or(Value::Null)
        } else {
            Value::Null
        };
        let obj = Object {
            class,
            body: ObjBody::Fields(vec![Value::Int(rng.range_i64(0, 1 << 20)), chain]),
            zygote_seq: None, // assigned by alloc_zygote
            dirty: true,      // cleared by alloc_zygote
            epoch: 0,         // template objects predate every sync point
        };
        let id = heap.alloc_zygote(obj);
        prev = Some(Value::Ref(id));
    }
    heap
}

/// Root the WHOLE template graph from an app static: a registry
/// `RefArray` referencing every Zygote-named object, parked in
/// `statics[class][slot]` — the shape where framework state (resource
/// tables, interned strings) keeps the template reachable, which the
/// Zygote-scale benches and soak tests exercise. Pre-session setup:
/// the array rides the normal allocator, the static slot is written
/// directly (as app builders do before the first sync point).
pub fn root_template_in_static(p: &mut Process, class: usize, slot: usize) {
    let mut zy: Vec<ObjId> = p
        .heap
        .iter()
        .filter(|(_, o)| o.zygote_seq.is_some())
        .map(|(id, _)| id)
        .collect();
    zy.sort_unstable();
    let refs: Vec<Value> = zy.into_iter().map(Value::Ref).collect();
    let arr_class = p.array_class;
    let arr = p.heap.alloc_ref_array(arr_class, refs.len());
    if let Some(obj) = p.heap.peek_mut(arr) {
        if let ObjBody::RefArray(v) = &mut obj.body {
            v.copy_from_slice(&refs);
        }
    }
    p.statics[class][slot] = Value::Ref(arr);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn program() -> Arc<Program> {
        let mut p = Program::new();
        install_system_classes(&mut p);
        p.into_shared()
    }

    #[test]
    fn template_is_deterministic_across_boots() {
        let p = program();
        let a = build_template(&p, 1000, 42);
        let b = build_template(&p, 1000, 42);
        // Identical ids, classes, sequences, payloads.
        let mut ids_a: Vec<_> = a.iter().map(|(id, _)| id).collect();
        let mut ids_b: Vec<_> = b.iter().map(|(id, _)| id).collect();
        ids_a.sort_unstable();
        ids_b.sort_unstable();
        assert_eq!(ids_a, ids_b);
        for id in ids_a {
            assert_eq!(a.get(id).unwrap(), b.get(id).unwrap());
        }
    }

    #[test]
    fn template_objects_are_clean_with_seq_names() {
        let p = program();
        let h = build_template(&p, 500, 1);
        for (_, obj) in h.iter() {
            assert!(!obj.dirty);
            assert!(obj.zygote_seq.is_some());
        }
        assert_eq!(h.len(), 500);
    }

    #[test]
    fn class_seq_pairs_are_unique() {
        let p = program();
        let h = build_template(&p, 777, 9);
        let mut names: Vec<(ClassId, u32)> = h
            .iter()
            .map(|(_, o)| (o.class, o.zygote_seq.unwrap()))
            .collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before, "(class, seq) is a unique name");
    }

    #[test]
    fn install_is_idempotent() {
        let mut p = Program::new();
        install_system_classes(&mut p);
        let n = p.classes.len();
        install_system_classes(&mut p);
        assert_eq!(p.classes.len(), n);
    }
}
