//! VM-wide heap with monotonic object ids and mark-sweep collection.
//!
//! The migrator's capture traversal (paper §4.1) and the post-merge
//! orphan collection (§4.2) both rely on this module: capture walks
//! references from thread roots exactly like the mark phase; merge leaves
//! "orphaned" objects disconnected, and a subsequent sweep collects them.
//!
//! The heap also carries the **mutation epoch** behind delta migration:
//! every mutable access ([`Heap::get_mut`] — the write barrier all
//! interpreter stores go through) stamps the object with the current
//! epoch, and the migrator advances the epoch at each migration sync
//! point. "Changed since the last sync" is then a single integer compare
//! (`obj.epoch > baseline_epoch`), which is what lets a capture ship only
//! the dirty set instead of the whole reachable heap.

use std::collections::HashMap;

use super::bytecode::ClassId;
use super::value::{ObjBody, ObjId, Object, Value};
use crate::error::{CloneCloudError, Result};

/// The object heap of one VM process.
#[derive(Debug, Clone, Default)]
pub struct Heap {
    objects: HashMap<u64, Object>,
    next_id: u64,
    /// Per-class Zygote construction counters (for (class, seq) naming).
    zygote_counters: HashMap<ClassId, u32>,
    /// Current mutation epoch. Advanced by the migrator at each sync
    /// point; stamped onto objects by `alloc` and `get_mut`.
    epoch: u64,
}

impl Heap {
    pub fn new() -> Heap {
        Heap {
            objects: HashMap::new(),
            next_id: 1,
            zygote_counters: HashMap::new(),
            epoch: 0,
        }
    }

    /// Current mutation epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Advance the mutation epoch (a migration sync point); returns the
    /// new epoch. Objects mutated from now on are distinguishable from
    /// state the other endpoint already holds.
    pub fn advance_epoch(&mut self) -> u64 {
        self.epoch += 1;
        self.epoch
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    /// Allocate an object, assigning the next monotonic id. The object is
    /// stamped with the current mutation epoch (a freshly allocated
    /// object is by definition newer than any earlier sync point).
    pub fn alloc(&mut self, mut obj: Object) -> ObjId {
        let id = ObjId(self.next_id);
        self.next_id += 1;
        obj.epoch = self.epoch;
        self.objects.insert(id.0, obj);
        id
    }

    /// Allocate a Zygote (template) object: named by (class, seq) so two
    /// independently-booted Zygotes assign identical names (§4.3).
    pub fn alloc_zygote(&mut self, mut obj: Object) -> ObjId {
        let seq = self.zygote_counters.entry(obj.class).or_insert(0);
        obj.zygote_seq = Some(*seq);
        obj.dirty = false;
        *seq += 1;
        self.alloc(obj)
    }

    /// Allocate with a specific id (merge-side re-instantiation). The id
    /// counter is bumped past it so future ids stay unique.
    pub fn alloc_with_id(&mut self, id: ObjId, mut obj: Object) -> Result<()> {
        if self.objects.contains_key(&id.0) {
            return Err(CloneCloudError::vm(format!("object id {} already live", id.0)));
        }
        self.next_id = self.next_id.max(id.0 + 1);
        obj.epoch = self.epoch;
        self.objects.insert(id.0, obj);
        Ok(())
    }

    pub fn get(&self, id: ObjId) -> Result<&Object> {
        self.objects
            .get(&id.0)
            .ok_or_else(|| CloneCloudError::vm(format!("dangling reference to object {}", id.0)))
    }

    /// Mutable access — the write barrier. Every interpreter store goes
    /// through here; the object is marked dirty (Zygote-diff, §4.3) and
    /// stamped with the current mutation epoch (delta migration).
    pub fn get_mut(&mut self, id: ObjId) -> Result<&mut Object> {
        let epoch = self.epoch;
        let o = self
            .objects
            .get_mut(&id.0)
            .ok_or_else(|| CloneCloudError::vm(format!("dangling reference to object {}", id.0)))?;
        o.dirty = true;
        o.epoch = epoch;
        Ok(o)
    }

    /// Mutable access that bypasses the write barrier: neither the dirty
    /// bit nor the mutation epoch is touched (bench/test setup only).
    pub fn peek_mut(&mut self, id: ObjId) -> Option<&mut Object> {
        self.objects.get_mut(&id.0)
    }

    pub fn contains(&self, id: ObjId) -> bool {
        self.objects.contains_key(&id.0)
    }

    pub fn remove(&mut self, id: ObjId) -> Option<Object> {
        self.objects.remove(&id.0)
    }

    /// Iterate (id, object) in unspecified order.
    pub fn iter(&self) -> impl Iterator<Item = (ObjId, &Object)> {
        self.objects.iter().map(|(k, v)| (ObjId(*k), v))
    }

    /// Transitive closure of references from `roots` — the mark phase,
    /// identical to the capture traversal of §4.1.
    pub fn reachable(&self, roots: &[ObjId]) -> Vec<ObjId> {
        let mut seen: HashMap<u64, ()> = HashMap::new();
        let mut stack: Vec<ObjId> = roots.to_vec();
        let mut out = Vec::new();
        while let Some(id) = stack.pop() {
            if seen.insert(id.0, ()).is_some() {
                continue;
            }
            if let Some(obj) = self.objects.get(&id.0) {
                out.push(id);
                stack.extend(obj.body.refs());
            }
        }
        out.sort_unstable();
        out
    }

    /// Mark-sweep: drop every object unreachable from `roots`. Returns the
    /// number collected.
    pub fn gc(&mut self, roots: &[ObjId]) -> usize {
        let live = self.reachable(roots);
        let live_set: HashMap<u64, ()> = live.iter().map(|r| (r.0, ())).collect();
        let before = self.objects.len();
        self.objects.retain(|id, _| live_set.contains_key(id));
        before - self.objects.len()
    }

    /// Total approximate byte size of a set of objects.
    pub fn byte_size_of(&self, ids: &[ObjId]) -> u64 {
        ids.iter()
            .filter_map(|id| self.objects.get(&id.0))
            .map(|o| o.byte_size())
            .sum()
    }

    /// Next id that will be assigned (for tests / diagnostics).
    pub fn next_id_hint(&self) -> u64 {
        self.next_id
    }

    /// Ids of every Zygote-named object (clean or dirtied). Slot GC
    /// roots these: template objects must stay resolvable by their
    /// (class, seq) name however unreachable they look right now.
    pub fn zygote_ids(&self) -> Vec<ObjId> {
        self.objects
            .iter()
            .filter(|(_, o)| o.zygote_seq.is_some())
            .map(|(&id, _)| ObjId(id))
            .collect()
    }
}

/// Helpers for building common objects.
impl Heap {
    pub fn alloc_byte_array(&mut self, class: ClassId, bytes: Vec<u8>) -> ObjId {
        self.alloc(Object {
            class,
            body: ObjBody::ByteArray(bytes),
            zygote_seq: None,
            dirty: true,
            epoch: 0,
        })
    }

    pub fn alloc_float_array(&mut self, class: ClassId, xs: Vec<f32>) -> ObjId {
        self.alloc(Object {
            class,
            body: ObjBody::FloatArray(xs),
            zygote_seq: None,
            dirty: true,
            epoch: 0,
        })
    }

    pub fn alloc_ref_array(&mut self, class: ClassId, n: usize) -> ObjId {
        self.alloc(Object {
            class,
            body: ObjBody::RefArray(vec![Value::Null; n]),
            zygote_seq: None,
            dirty: true,
            epoch: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn heap_with_chain() -> (Heap, ObjId, ObjId, ObjId) {
        // a -> b -> c
        let mut h = Heap::new();
        let c = h.alloc(Object::new_fields(ClassId(0), 1));
        let b = {
            let mut o = Object::new_fields(ClassId(0), 1);
            o.body = ObjBody::Fields(vec![Value::Ref(c)]);
            h.alloc(o)
        };
        let a = {
            let mut o = Object::new_fields(ClassId(0), 1);
            o.body = ObjBody::Fields(vec![Value::Ref(b)]);
            h.alloc(o)
        };
        (h, a, b, c)
    }

    #[test]
    fn ids_are_monotonic_and_unique() {
        let mut h = Heap::new();
        let a = h.alloc(Object::new_fields(ClassId(0), 0));
        let b = h.alloc(Object::new_fields(ClassId(0), 0));
        assert!(b.0 > a.0);
        h.remove(a);
        let c = h.alloc(Object::new_fields(ClassId(0), 0));
        assert!(c.0 > b.0, "ids never reused even after free");
    }

    #[test]
    fn reachability_follows_chains() {
        let (h, a, b, c) = heap_with_chain();
        let r = h.reachable(&[a]);
        assert_eq!(r, {
            let mut v = vec![a, b, c];
            v.sort_unstable();
            v
        });
        assert_eq!(h.reachable(&[c]).len(), 1);
    }

    #[test]
    fn reachability_handles_cycles() {
        let mut h = Heap::new();
        let a = h.alloc(Object::new_fields(ClassId(0), 1));
        let b = h.alloc(Object::new_fields(ClassId(0), 1));
        h.get_mut(a).unwrap().body = ObjBody::Fields(vec![Value::Ref(b)]);
        h.get_mut(b).unwrap().body = ObjBody::Fields(vec![Value::Ref(a)]);
        assert_eq!(h.reachable(&[a]).len(), 2);
    }

    #[test]
    fn gc_collects_orphans() {
        let (mut h, a, _b, c) = heap_with_chain();
        // Cut b -> c.
        let b_id = h.get(a).unwrap().body.refs()[0];
        h.get_mut(b_id).unwrap().body = ObjBody::Fields(vec![Value::Null]);
        let collected = h.gc(&[a]);
        assert_eq!(collected, 1);
        assert!(!h.contains(c));
        assert!(h.contains(a));
    }

    #[test]
    fn zygote_naming_is_per_class_sequence() {
        let mut h = Heap::new();
        let a = h.alloc_zygote(Object::new_fields(ClassId(3), 0));
        let b = h.alloc_zygote(Object::new_fields(ClassId(3), 0));
        let c = h.alloc_zygote(Object::new_fields(ClassId(4), 0));
        assert_eq!(h.get(a).unwrap().zygote_seq, Some(0));
        assert_eq!(h.get(b).unwrap().zygote_seq, Some(1));
        assert_eq!(h.get(c).unwrap().zygote_seq, Some(0), "per-class counter");
        assert!(!h.get(a).unwrap().dirty);
    }

    #[test]
    fn get_mut_sets_dirty() {
        let mut h = Heap::new();
        let a = h.alloc_zygote(Object::new_fields(ClassId(0), 1));
        assert!(!h.get(a).unwrap().dirty);
        h.get_mut(a).unwrap();
        assert!(h.get(a).unwrap().dirty);
    }

    #[test]
    fn write_barrier_stamps_mutation_epoch() {
        let mut h = Heap::new();
        let a = h.alloc(Object::new_fields(ClassId(0), 1));
        assert_eq!(h.get(a).unwrap().epoch, 0, "allocated in epoch 0");

        assert_eq!(h.advance_epoch(), 1);
        assert_eq!(h.get(a).unwrap().epoch, 0, "untouched objects keep their stamp");
        h.get_mut(a).unwrap();
        assert_eq!(h.get(a).unwrap().epoch, 1, "mutation stamps the current epoch");

        let b = h.alloc(Object::new_fields(ClassId(0), 1));
        assert_eq!(h.get(b).unwrap().epoch, 1, "allocation stamps the current epoch");

        // peek_mut bypasses the barrier entirely.
        h.advance_epoch();
        h.peek_mut(a).unwrap();
        assert_eq!(h.get(a).unwrap().epoch, 1);
    }

    #[test]
    fn alloc_with_id_bumps_counter_and_rejects_dup() {
        let mut h = Heap::new();
        h.alloc_with_id(ObjId(100), Object::new_fields(ClassId(0), 0))
            .unwrap();
        assert!(h
            .alloc_with_id(ObjId(100), Object::new_fields(ClassId(0), 0))
            .is_err());
        let next = h.alloc(Object::new_fields(ClassId(0), 0));
        assert!(next.0 > 100);
    }

    #[test]
    fn dangling_reference_is_a_fault() {
        let h = Heap::new();
        assert!(h.get(ObjId(99)).is_err());
    }
}
