//! VM-wide heap with monotonic object ids and mark-sweep collection.
//!
//! The migrator's capture traversal (paper §4.1) and the post-merge
//! orphan collection (§4.2) both rely on this module: capture walks
//! references from thread roots exactly like the mark phase; merge leaves
//! "orphaned" objects disconnected, and a subsequent sweep collects them.
//!
//! The heap also carries the **mutation epoch** behind delta migration:
//! every mutable access ([`Heap::get_mut`] — the write barrier all
//! interpreter stores go through) stamps the object with the current
//! epoch, and the migrator advances the epoch at each migration sync
//! point. "Changed since the last sync" is then a single integer compare
//! (`obj.epoch > baseline_epoch`), which is what lets a capture ship only
//! the dirty set instead of the whole reachable heap.

use std::collections::HashMap;

use super::bytecode::ClassId;
use super::value::{ObjBody, ObjId, Object, Value};
use crate::error::{CloneCloudError, Result};

/// Object-ids per heap page (`1 << PAGE_SHIFT`). Ids are monotonic, so a
/// page is a fixed contiguous id range; each page carries the max epoch
/// ever stamped onto it by the same barriers that stamp objects. A delta
/// capture compares the page epoch once and skips a clean page wholesale,
/// making the dirty scan O(dirty pages) instead of O(heap).
pub const PAGE_SHIFT: u32 = 6;
/// Object-ids per heap page (64).
pub const PAGE_OBJECTS: u64 = 1 << PAGE_SHIFT;

/// Result of one page scan: every live object stamped after `base_epoch`
/// (in id order, so capsules stay deterministic), plus the ids on dirty
/// pages removed since the sync — the deletion signal (`Heap::remove` and
/// `Heap::gc` record a per-page tombstone and stamp the page for every id
/// they drop). Tombstones carry their removal epoch, so a page redirtied
/// long after a removal reports only removals newer than the baseline —
/// scan output shrinks on removal-heavy workloads instead of re-listing
/// every hole forever. The counters feed the `pages_scanned`/`pages_dirty`
/// capture metrics.
#[derive(Debug, Clone, Default)]
pub struct PageScan {
    /// Live objects with `epoch > base_epoch`, ascending by id.
    pub dirty: Vec<ObjId>,
    /// Ids on scanned pages with no live object behind them.
    pub missing: Vec<u64>,
    /// Pages that exist (have ever been stamped or allocated into).
    pub pages_total: usize,
    /// Pages whose contents were actually examined (page epoch newer
    /// than the baseline).
    pub pages_scanned: usize,
    /// Scanned pages that yielded at least one live dirty object.
    pub pages_dirty: usize,
}

/// The object heap of one VM process.
#[derive(Debug, Clone, Default)]
pub struct Heap {
    objects: HashMap<u64, Object>,
    next_id: u64,
    /// Per-class Zygote construction counters (for (class, seq) naming).
    zygote_counters: HashMap<ClassId, u32>,
    /// Current mutation epoch. Advanced by the migrator at each sync
    /// point; stamped onto objects by `alloc` and `get_mut`.
    epoch: u64,
    /// Max epoch per id page (see [`PAGE_OBJECTS`]), maintained by the
    /// same barriers that stamp `Object::epoch` — plus `remove`/`gc`, so
    /// a page scan also surfaces deletions.
    page_epochs: Vec<u64>,
    /// Compacted per-page tombstones: `(offset-within-page, removal
    /// epoch)` for every id dropped from the page, kept sorted by offset.
    /// The paged scan reports removals straight off this list (filtered
    /// by the baseline epoch) instead of probing all `PAGE_OBJECTS` id
    /// slots for liveness holes.
    tombstones: HashMap<usize, Vec<(u16, u64)>>,
    /// Generation counter of the Zygote-named object set: bumped whenever
    /// an object carrying a `zygote_seq` name is added or removed. Lets a
    /// receive path cache its `ZygoteIndex` and invalidate only on
    /// template mutation.
    zygote_gen: u64,
}

impl Heap {
    pub fn new() -> Heap {
        Heap {
            objects: HashMap::new(),
            next_id: 1,
            zygote_counters: HashMap::new(),
            epoch: 0,
            page_epochs: Vec::new(),
            tombstones: HashMap::new(),
            zygote_gen: 0,
        }
    }

    /// Stamp the page holding `id` with the current epoch (epochs only
    /// grow, so assignment preserves the per-page max).
    #[inline]
    fn stamp_page(&mut self, id: u64) {
        let pi = (id >> PAGE_SHIFT) as usize;
        if pi >= self.page_epochs.len() {
            self.page_epochs.resize(pi + 1, 0);
        }
        self.page_epochs[pi] = self.epoch;
    }

    /// Record a removal tombstone for `id` at the current epoch. A
    /// re-removal (remove, resurrect via `alloc_with_id`, remove again)
    /// replaces the entry in place, so the list stays one entry per
    /// offset — compacted, never growing past `PAGE_OBJECTS`.
    fn note_removed(&mut self, id: u64) {
        let pi = (id >> PAGE_SHIFT) as usize;
        let off = (id & (PAGE_OBJECTS - 1)) as u16;
        let epoch = self.epoch;
        let list = self.tombstones.entry(pi).or_default();
        match list.binary_search_by_key(&off, |&(o, _)| o) {
            Ok(i) => list[i].1 = epoch,
            Err(i) => list.insert(i, (off, epoch)),
        }
    }

    /// Drop the tombstone for `id`, if any (resurrection via
    /// `alloc_with_id` — the id is live again, not removed).
    fn clear_tombstone(&mut self, id: u64) {
        if let Some(list) = self.tombstones.get_mut(&((id >> PAGE_SHIFT) as usize)) {
            let off = (id & (PAGE_OBJECTS - 1)) as u16;
            if let Ok(i) = list.binary_search_by_key(&off, |&(o, _)| o) {
                list.remove(i);
            }
        }
    }

    /// Number of id pages this heap spans.
    pub fn page_count(&self) -> usize {
        self.page_epochs.len()
    }

    /// Max epoch stamped onto a page (0 for pages never touched).
    pub fn page_epoch(&self, page: usize) -> u64 {
        self.page_epochs.get(page).copied().unwrap_or(0)
    }

    /// Scan only the pages stamped after `base_epoch` and return their
    /// dirty live objects plus the ids that vanished (removed objects).
    /// Work is O(dirty pages), not O(heap) — the whole point of the
    /// page-epoch layer.
    pub fn scan_dirty_pages(&self, base_epoch: u64) -> PageScan {
        let mut out = PageScan {
            pages_total: self.page_epochs.len(),
            ..PageScan::default()
        };
        for (pi, &pe) in self.page_epochs.iter().enumerate() {
            if pe <= base_epoch {
                continue;
            }
            out.pages_scanned += 1;
            let lo = ((pi as u64) << PAGE_SHIFT).max(1); // id 0 is never allocated
            let hi = (((pi as u64) + 1) << PAGE_SHIFT).min(self.next_id);
            let mut any = false;
            for id in lo..hi {
                if let Some(o) = self.objects.get(&id) {
                    if o.epoch > base_epoch {
                        out.dirty.push(ObjId(id));
                        any = true;
                    }
                }
            }
            // Removals come straight off the compacted tombstone list:
            // only ids dropped *after* the baseline are reported, so an
            // old removal stops riding along once the peer has synced
            // past it (the list is offset-sorted, so ids stay ascending).
            if let Some(list) = self.tombstones.get(&pi) {
                let page_base = (pi as u64) << PAGE_SHIFT;
                for &(off, removed_at) in list {
                    if removed_at > base_epoch {
                        out.missing.push(page_base + off as u64);
                    }
                }
            }
            if any {
                out.pages_dirty += 1;
            }
        }
        out
    }

    /// Current mutation epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Advance the mutation epoch (a migration sync point); returns the
    /// new epoch. Objects mutated from now on are distinguishable from
    /// state the other endpoint already holds.
    pub fn advance_epoch(&mut self) -> u64 {
        self.epoch += 1;
        self.epoch
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    /// Allocate an object, assigning the next monotonic id. The object is
    /// stamped with the current mutation epoch (a freshly allocated
    /// object is by definition newer than any earlier sync point).
    pub fn alloc(&mut self, mut obj: Object) -> ObjId {
        let id = ObjId(self.next_id);
        self.next_id += 1;
        obj.epoch = self.epoch;
        if obj.zygote_seq.is_some() {
            self.zygote_gen += 1;
        }
        self.objects.insert(id.0, obj);
        self.stamp_page(id.0);
        id
    }

    /// Allocate a Zygote (template) object: named by (class, seq) so two
    /// independently-booted Zygotes assign identical names (§4.3).
    pub fn alloc_zygote(&mut self, mut obj: Object) -> ObjId {
        let seq = self.zygote_counters.entry(obj.class).or_insert(0);
        obj.zygote_seq = Some(*seq);
        obj.dirty = false;
        *seq += 1;
        self.alloc(obj)
    }

    /// Allocate with a specific id (merge-side re-instantiation). The id
    /// counter is bumped past it so future ids stay unique.
    pub fn alloc_with_id(&mut self, id: ObjId, mut obj: Object) -> Result<()> {
        if self.objects.contains_key(&id.0) {
            return Err(CloneCloudError::vm(format!("object id {} already live", id.0)));
        }
        self.next_id = self.next_id.max(id.0 + 1);
        obj.epoch = self.epoch;
        if obj.zygote_seq.is_some() {
            self.zygote_gen += 1;
        }
        self.objects.insert(id.0, obj);
        self.stamp_page(id.0);
        self.clear_tombstone(id.0);
        Ok(())
    }

    pub fn get(&self, id: ObjId) -> Result<&Object> {
        self.objects
            .get(&id.0)
            .ok_or_else(|| CloneCloudError::vm(format!("dangling reference to object {}", id.0)))
    }

    /// Mutable access — the write barrier. Every interpreter store goes
    /// through here; the object is marked dirty (Zygote-diff, §4.3) and
    /// stamped with the current mutation epoch (delta migration).
    pub fn get_mut(&mut self, id: ObjId) -> Result<&mut Object> {
        let epoch = self.epoch;
        if self.objects.contains_key(&id.0) {
            self.stamp_page(id.0);
        }
        let o = self
            .objects
            .get_mut(&id.0)
            .ok_or_else(|| CloneCloudError::vm(format!("dangling reference to object {}", id.0)))?;
        o.dirty = true;
        o.epoch = epoch;
        Ok(o)
    }

    /// Mutable access that bypasses the write barrier: neither the dirty
    /// bit nor the mutation epoch is touched (bench/test setup only).
    pub fn peek_mut(&mut self, id: ObjId) -> Option<&mut Object> {
        self.objects.get_mut(&id.0)
    }

    pub fn contains(&self, id: ObjId) -> bool {
        self.objects.contains_key(&id.0)
    }

    pub fn remove(&mut self, id: ObjId) -> Option<Object> {
        let gone = self.objects.remove(&id.0);
        if let Some(o) = &gone {
            if o.zygote_seq.is_some() {
                self.zygote_gen += 1;
            }
            // A removal is a mutation of the page: the delta scan reports
            // the vanished id (off the tombstone list), which is how
            // deletions reach the peer.
            self.stamp_page(id.0);
            self.note_removed(id.0);
        }
        gone
    }

    /// Iterate (id, object) in unspecified order.
    pub fn iter(&self) -> impl Iterator<Item = (ObjId, &Object)> {
        self.objects.iter().map(|(k, v)| (ObjId(*k), v))
    }

    /// Transitive closure of references from `roots` — the mark phase,
    /// identical to the capture traversal of §4.1.
    pub fn reachable(&self, roots: &[ObjId]) -> Vec<ObjId> {
        let mut seen: HashMap<u64, ()> = HashMap::new();
        let mut stack: Vec<ObjId> = roots.to_vec();
        let mut out = Vec::new();
        while let Some(id) = stack.pop() {
            if seen.insert(id.0, ()).is_some() {
                continue;
            }
            if let Some(obj) = self.objects.get(&id.0) {
                out.push(id);
                stack.extend(obj.body.refs());
            }
        }
        out.sort_unstable();
        out
    }

    /// Mark-sweep: drop every object unreachable from `roots`. Returns the
    /// number collected.
    pub fn gc(&mut self, roots: &[ObjId]) -> usize {
        let live = self.reachable(roots);
        let live_set: HashMap<u64, ()> = live.iter().map(|r| (r.0, ())).collect();
        let dead: Vec<u64> = self
            .objects
            .keys()
            .filter(|id| !live_set.contains_key(id))
            .copied()
            .collect();
        for &id in &dead {
            if let Some(o) = self.objects.remove(&id) {
                if o.zygote_seq.is_some() {
                    self.zygote_gen += 1;
                }
            }
            // Stamp every page a collected id lived on and tombstone the
            // id: the delta scan's missing-id pass is how the peer learns
            // about deletions.
            self.stamp_page(id);
            self.note_removed(id);
        }
        dead.len()
    }

    /// Total approximate byte size of a set of objects.
    pub fn byte_size_of(&self, ids: &[ObjId]) -> u64 {
        ids.iter()
            .filter_map(|id| self.objects.get(&id.0))
            .map(|o| o.byte_size())
            .sum()
    }

    /// Next id that will be assigned (for tests / diagnostics).
    pub fn next_id_hint(&self) -> u64 {
        self.next_id
    }

    /// Generation of the Zygote-named object set: changes iff a
    /// `zygote_seq`-carrying object was added or removed since the last
    /// observation. A cached `ZygoteIndex` built at generation G stays
    /// valid while `zygote_gen() == G` (template bodies may mutate — the
    /// (class, seq) → id mapping doesn't care).
    pub fn zygote_gen(&self) -> u64 {
        self.zygote_gen
    }

    /// Ids of every Zygote-named object (clean or dirtied). Slot GC
    /// roots these: template objects must stay resolvable by their
    /// (class, seq) name however unreachable they look right now.
    pub fn zygote_ids(&self) -> Vec<ObjId> {
        self.objects
            .iter()
            .filter(|(_, o)| o.zygote_seq.is_some())
            .map(|(&id, _)| ObjId(id))
            .collect()
    }
}

/// Helpers for building common objects.
impl Heap {
    pub fn alloc_byte_array(&mut self, class: ClassId, bytes: Vec<u8>) -> ObjId {
        self.alloc(Object {
            class,
            body: ObjBody::ByteArray(bytes),
            zygote_seq: None,
            dirty: true,
            epoch: 0,
        })
    }

    pub fn alloc_float_array(&mut self, class: ClassId, xs: Vec<f32>) -> ObjId {
        self.alloc(Object {
            class,
            body: ObjBody::FloatArray(xs),
            zygote_seq: None,
            dirty: true,
            epoch: 0,
        })
    }

    pub fn alloc_ref_array(&mut self, class: ClassId, n: usize) -> ObjId {
        self.alloc(Object {
            class,
            body: ObjBody::RefArray(vec![Value::Null; n]),
            zygote_seq: None,
            dirty: true,
            epoch: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn heap_with_chain() -> (Heap, ObjId, ObjId, ObjId) {
        // a -> b -> c
        let mut h = Heap::new();
        let c = h.alloc(Object::new_fields(ClassId(0), 1));
        let b = {
            let mut o = Object::new_fields(ClassId(0), 1);
            o.body = ObjBody::Fields(vec![Value::Ref(c)]);
            h.alloc(o)
        };
        let a = {
            let mut o = Object::new_fields(ClassId(0), 1);
            o.body = ObjBody::Fields(vec![Value::Ref(b)]);
            h.alloc(o)
        };
        (h, a, b, c)
    }

    #[test]
    fn ids_are_monotonic_and_unique() {
        let mut h = Heap::new();
        let a = h.alloc(Object::new_fields(ClassId(0), 0));
        let b = h.alloc(Object::new_fields(ClassId(0), 0));
        assert!(b.0 > a.0);
        h.remove(a);
        let c = h.alloc(Object::new_fields(ClassId(0), 0));
        assert!(c.0 > b.0, "ids never reused even after free");
    }

    #[test]
    fn reachability_follows_chains() {
        let (h, a, b, c) = heap_with_chain();
        let r = h.reachable(&[a]);
        assert_eq!(r, {
            let mut v = vec![a, b, c];
            v.sort_unstable();
            v
        });
        assert_eq!(h.reachable(&[c]).len(), 1);
    }

    #[test]
    fn reachability_handles_cycles() {
        let mut h = Heap::new();
        let a = h.alloc(Object::new_fields(ClassId(0), 1));
        let b = h.alloc(Object::new_fields(ClassId(0), 1));
        h.get_mut(a).unwrap().body = ObjBody::Fields(vec![Value::Ref(b)]);
        h.get_mut(b).unwrap().body = ObjBody::Fields(vec![Value::Ref(a)]);
        assert_eq!(h.reachable(&[a]).len(), 2);
    }

    #[test]
    fn gc_collects_orphans() {
        let (mut h, a, _b, c) = heap_with_chain();
        // Cut b -> c.
        let b_id = h.get(a).unwrap().body.refs()[0];
        h.get_mut(b_id).unwrap().body = ObjBody::Fields(vec![Value::Null]);
        let collected = h.gc(&[a]);
        assert_eq!(collected, 1);
        assert!(!h.contains(c));
        assert!(h.contains(a));
    }

    #[test]
    fn zygote_naming_is_per_class_sequence() {
        let mut h = Heap::new();
        let a = h.alloc_zygote(Object::new_fields(ClassId(3), 0));
        let b = h.alloc_zygote(Object::new_fields(ClassId(3), 0));
        let c = h.alloc_zygote(Object::new_fields(ClassId(4), 0));
        assert_eq!(h.get(a).unwrap().zygote_seq, Some(0));
        assert_eq!(h.get(b).unwrap().zygote_seq, Some(1));
        assert_eq!(h.get(c).unwrap().zygote_seq, Some(0), "per-class counter");
        assert!(!h.get(a).unwrap().dirty);
    }

    #[test]
    fn get_mut_sets_dirty() {
        let mut h = Heap::new();
        let a = h.alloc_zygote(Object::new_fields(ClassId(0), 1));
        assert!(!h.get(a).unwrap().dirty);
        h.get_mut(a).unwrap();
        assert!(h.get(a).unwrap().dirty);
    }

    #[test]
    fn write_barrier_stamps_mutation_epoch() {
        let mut h = Heap::new();
        let a = h.alloc(Object::new_fields(ClassId(0), 1));
        assert_eq!(h.get(a).unwrap().epoch, 0, "allocated in epoch 0");

        assert_eq!(h.advance_epoch(), 1);
        assert_eq!(h.get(a).unwrap().epoch, 0, "untouched objects keep their stamp");
        h.get_mut(a).unwrap();
        assert_eq!(h.get(a).unwrap().epoch, 1, "mutation stamps the current epoch");

        let b = h.alloc(Object::new_fields(ClassId(0), 1));
        assert_eq!(h.get(b).unwrap().epoch, 1, "allocation stamps the current epoch");

        // peek_mut bypasses the barrier entirely.
        h.advance_epoch();
        h.peek_mut(a).unwrap();
        assert_eq!(h.get(a).unwrap().epoch, 1);
    }

    #[test]
    fn alloc_with_id_bumps_counter_and_rejects_dup() {
        let mut h = Heap::new();
        h.alloc_with_id(ObjId(100), Object::new_fields(ClassId(0), 0))
            .unwrap();
        assert!(h
            .alloc_with_id(ObjId(100), Object::new_fields(ClassId(0), 0))
            .is_err());
        let next = h.alloc(Object::new_fields(ClassId(0), 0));
        assert!(next.0 > 100);
    }

    #[test]
    fn dangling_reference_is_a_fault() {
        let h = Heap::new();
        assert!(h.get(ObjId(99)).is_err());
    }

    #[test]
    fn page_epochs_track_every_barrier() {
        let mut h = Heap::new();
        // Fill a bit more than one page so two pages exist.
        let ids: Vec<ObjId> = (0..PAGE_OBJECTS + 8)
            .map(|_| h.alloc(Object::new_fields(ClassId(0), 1)))
            .collect();
        assert_eq!(h.page_count(), 2);
        assert_eq!(h.page_epoch(0), 0);

        let base = h.advance_epoch() - 1; // baseline recorded at epoch 0
        let scan = h.scan_dirty_pages(base);
        assert!(scan.dirty.is_empty(), "nothing written since the sync");
        assert_eq!(scan.pages_scanned, 0, "clean pages skipped wholesale");

        // One store dirties exactly one page.
        h.get_mut(ids[3]).unwrap();
        let scan = h.scan_dirty_pages(base);
        assert_eq!(scan.dirty, vec![ids[3]]);
        assert_eq!(scan.pages_scanned, 1);
        assert_eq!(scan.pages_dirty, 1);
        assert!(scan.missing.is_empty());

        // An allocation stamps its page too.
        let fresh = h.alloc(Object::new_fields(ClassId(0), 0));
        let scan = h.scan_dirty_pages(base);
        assert!(scan.dirty.contains(&fresh));

        // peek_mut bypasses the page barrier exactly like the object one.
        h.advance_epoch();
        let base2 = h.epoch() - 1;
        h.peek_mut(ids[5]).unwrap();
        assert!(h.scan_dirty_pages(base2).dirty.is_empty());
    }

    #[test]
    fn removals_surface_as_missing_ids_on_dirty_pages() {
        let mut h = Heap::new();
        let ids: Vec<ObjId> = (0..10)
            .map(|_| h.alloc(Object::new_fields(ClassId(0), 0)))
            .collect();
        let base = h.epoch();
        h.advance_epoch();
        h.remove(ids[4]);
        let scan = h.scan_dirty_pages(base);
        assert!(scan.missing.contains(&ids[4].0));
        assert!(!scan.missing.contains(&0), "id 0 never existed");
        assert_eq!(scan.pages_scanned, 1);
        assert_eq!(scan.pages_dirty, 0, "no live dirty object on the page");

        // gc() stamps the pages of everything it sweeps.
        let keep = ids[0];
        h.advance_epoch();
        let base2 = h.epoch() - 1;
        let collected = h.gc(&[keep]);
        assert!(collected >= 8);
        let scan = h.scan_dirty_pages(base2);
        assert!(scan.missing.len() >= 8, "sweep reported: {scan:?}");

        // A later baseline no longer sees the old removals.
        h.advance_epoch();
        assert!(h.scan_dirty_pages(h.epoch()).missing.is_empty());
    }

    #[test]
    fn old_removals_stop_riding_redirtied_pages() {
        let mut h = Heap::new();
        let ids: Vec<ObjId> = (0..10)
            .map(|_| h.alloc(Object::new_fields(ClassId(0), 1)))
            .collect();
        let base = h.epoch();
        h.advance_epoch();
        h.remove(ids[4]);
        assert_eq!(h.scan_dirty_pages(base).missing, vec![ids[4].0]);

        // Sync past the removal, then redirty the same page: the old
        // tombstone is epoch-filtered out — only the fresh write shows.
        let base2 = h.epoch();
        h.advance_epoch();
        h.get_mut(ids[7]).unwrap();
        let scan = h.scan_dirty_pages(base2);
        assert_eq!(scan.dirty, vec![ids[7]]);
        assert!(scan.missing.is_empty(), "pre-baseline removal re-reported");

        // A re-removal after resurrection replaces the tombstone in place.
        h.alloc_with_id(ids[4], Object::new_fields(ClassId(0), 1))
            .unwrap();
        let base3 = h.epoch();
        h.advance_epoch();
        h.remove(ids[4]);
        let scan = h.scan_dirty_pages(base3);
        assert_eq!(scan.missing, vec![ids[4].0]);
        let page = (ids[4].0 >> PAGE_SHIFT) as usize;
        assert_eq!(h.tombstones[&page].len(), 1, "one entry per offset");
    }

    #[test]
    fn resurrection_clears_the_tombstone() {
        let mut h = Heap::new();
        let a = h.alloc(Object::new_fields(ClassId(0), 1));
        let base = h.epoch();
        h.advance_epoch();
        h.remove(a);
        h.alloc_with_id(a, Object::new_fields(ClassId(0), 1)).unwrap();
        let scan = h.scan_dirty_pages(base);
        assert!(scan.missing.is_empty(), "live id reported as removed");
        assert_eq!(scan.dirty, vec![a]);
    }

    #[test]
    fn zygote_generation_tracks_template_set() {
        let mut h = Heap::new();
        let g0 = h.zygote_gen();
        let app = h.alloc(Object::new_fields(ClassId(0), 1));
        assert_eq!(h.zygote_gen(), g0, "app objects don't move the gen");
        let z = h.alloc_zygote(Object::new_fields(ClassId(1), 1));
        assert!(h.zygote_gen() > g0, "template addition bumps");
        let g1 = h.zygote_gen();
        h.get_mut(z).unwrap();
        assert_eq!(h.zygote_gen(), g1, "template mutation keeps the name map");
        h.remove(app);
        assert_eq!(h.zygote_gen(), g1, "app removal doesn't move the gen");
        h.remove(z);
        assert!(h.zygote_gen() > g1, "template removal bumps");
    }

    #[test]
    fn dirty_scan_is_in_id_order_and_deterministic() {
        let mut h = Heap::new();
        let ids: Vec<ObjId> = (0..200)
            .map(|_| h.alloc(Object::new_fields(ClassId(0), 1)))
            .collect();
        let base = h.epoch();
        h.advance_epoch();
        for &i in &[150usize, 3, 77, 42, 199] {
            h.get_mut(ids[i]).unwrap();
        }
        let scan = h.scan_dirty_pages(base);
        let mut want: Vec<ObjId> = [150usize, 3, 77, 42, 199].iter().map(|&i| ids[i]).collect();
        want.sort_unstable();
        assert_eq!(scan.dirty, want);
        assert!(scan.pages_scanned <= 5);
        assert!(scan.pages_total >= 3);
    }
}
