//! Bytecode verifier.
//!
//! Static well-formedness checks run at load time (and after the
//! partitioner's rewriter touches a binary): register indices within the
//! frame, branch targets inside the method, invoke arity matching the
//! callee, field/static indices in range, terminal instruction present.
//! A rewritten executable must re-verify — this catches rewriter bugs
//! before they become migration-time faults.

use super::bytecode::{Instr, MRef};
use super::class::Program;
use crate::error::{CloneCloudError, Result};

fn verr(p: &Program, m: MRef, msg: impl Into<String>) -> CloneCloudError {
    CloneCloudError::Verify {
        method: p.method_name(m),
        message: msg.into(),
    }
}

/// Verify every method of the program.
pub fn verify_program(p: &Program) -> Result<()> {
    for mref in p.all_methods() {
        verify_method(p, mref)?;
    }
    Ok(())
}

/// Verify one method.
pub fn verify_method(p: &Program, mref: MRef) -> Result<()> {
    let m = p.method(mref);
    if m.is_native() {
        if !m.code.is_empty() {
            return Err(verr(p, mref, "native method with bytecode"));
        }
        return Ok(());
    }
    if m.code.is_empty() {
        return Err(verr(p, mref, "empty body"));
    }
    if m.nregs < m.nargs {
        return Err(verr(p, mref, "fewer registers than arguments"));
    }
    if m.nregs > u8::MAX as usize + 1 {
        return Err(verr(p, mref, "more than 256 registers"));
    }
    let nregs = m.nregs;
    let len = m.code.len() as u32;

    let chk_reg = |r: u8| -> Result<()> {
        if (r as usize) < nregs {
            Ok(())
        } else {
            Err(verr(p, mref, format!("register r{r} out of range (regs={nregs})")))
        }
    };
    let chk_target = |t: u32| -> Result<()> {
        if t < len {
            Ok(())
        } else {
            Err(verr(p, mref, format!("branch target {t} out of range (len={len})")))
        }
    };

    for (pc, instr) in m.code.iter().enumerate() {
        match instr {
            Instr::Nop | Instr::CcStart(_) | Instr::CcStop(_) => {}
            Instr::Const(d, _) | Instr::ConstF(d, _) => chk_reg(*d)?,
            Instr::Move(d, s)
            | Instr::ArrLen(d, s)
            | Instr::IntToFloat(d, s)
            | Instr::FloatToInt(d, s) => {
                chk_reg(*d)?;
                chk_reg(*s)?;
            }
            Instr::IntBin(_, d, a, b)
            | Instr::FloatBin(_, d, a, b)
            | Instr::Cmp(_, d, a, b)
            | Instr::ArrGet(d, a, b)
            | Instr::ArrPut(d, a, b) => {
                chk_reg(*d)?;
                chk_reg(*a)?;
                chk_reg(*b)?;
            }
            Instr::IfZ(r, t) | Instr::IfNZ(r, t) => {
                chk_reg(*r)?;
                chk_target(*t)?;
            }
            Instr::IfCmp(_, a, b, t) => {
                chk_reg(*a)?;
                chk_reg(*b)?;
                chk_target(*t)?;
            }
            Instr::Goto(t) => chk_target(*t)?,
            Instr::Invoke { mref: callee, ret, args } => {
                if callee.class.0 as usize >= p.classes.len() {
                    return Err(verr(p, mref, "invoke: class out of range"));
                }
                let cdef = p.class(callee.class);
                if callee.method.0 as usize >= cdef.methods.len() {
                    return Err(verr(p, mref, "invoke: method out of range"));
                }
                let callee_def = p.method(*callee);
                if args.len() != callee_def.nargs {
                    return Err(verr(
                        p,
                        mref,
                        format!(
                            "invoke {} with {} args (wants {})",
                            p.method_name(*callee),
                            args.len(),
                            callee_def.nargs
                        ),
                    ));
                }
                if let Some(r) = ret {
                    chk_reg(*r)?;
                }
                for a in args {
                    chk_reg(*a)?;
                }
            }
            Instr::Return(Some(r)) => chk_reg(*r)?,
            Instr::Return(None) => {}
            Instr::New(d, class) => {
                chk_reg(*d)?;
                if class.0 as usize >= p.classes.len() {
                    return Err(verr(p, mref, "new: class out of range"));
                }
            }
            Instr::GetField(d, o, idx) => {
                chk_reg(*d)?;
                chk_reg(*o)?;
                // Field index can't be checked against a class statically
                // (objects are untyped); bound it loosely.
                let _ = idx;
            }
            Instr::PutField(o, _idx, s) => {
                chk_reg(*o)?;
                chk_reg(*s)?;
            }
            Instr::GetStatic(d, class, idx) => {
                chk_reg(*d)?;
                chk_static(p, mref, *class, *idx)?;
            }
            Instr::PutStatic(class, idx, s) => {
                chk_reg(*s)?;
                chk_static(p, mref, *class, *idx)?;
            }
            Instr::NewArray(d, _, l) => {
                chk_reg(*d)?;
                chk_reg(*l)?;
            }
        }
        // Fall-through off the end: last instruction must be terminal
        // (return or unconditional branch).
        if pc as u32 == len - 1 {
            match instr {
                Instr::Return(_) | Instr::Goto(_) => {}
                _ => return Err(verr(p, mref, "method can fall off the end")),
            }
        }
    }
    Ok(())
}

fn chk_static(
    p: &Program,
    m: MRef,
    class: super::bytecode::ClassId,
    idx: u16,
) -> Result<()> {
    if class.0 as usize >= p.classes.len() {
        return Err(verr(p, m, "static: class out of range"));
    }
    if idx as usize >= p.class(class).statics.len() {
        return Err(verr(p, m, format!("static index {idx} out of range")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::bytecode::{ClassId, MethodId};
    use crate::appvm::class::{ClassDef, MethodDef};

    fn method(code: Vec<Instr>, nregs: usize) -> Program {
        let mut p = Program::new();
        let mut c = ClassDef::new("T", false);
        c.add_static("s");
        c.add_method(MethodDef {
            name: "main".into(),
            nargs: 0,
            nregs,
            code,
            native: None,
            pinned: true,
            native_state: false,
            migration_point: None,
        });
        p.add_class(c);
        p
    }

    #[test]
    fn accepts_valid_assembled_program() {
        let p = assemble(
            "class A app\n  method main nargs=0 regs=3\n    const r0 1\n    ifz r0 @x\n  x:\n    retv\n  end\nend\n",
        )
        .unwrap();
        verify_program(&p).unwrap();
    }

    #[test]
    fn rejects_register_out_of_range() {
        let p = method(vec![Instr::Const(5, 1), Instr::Return(None)], 2);
        let e = verify_program(&p).unwrap_err().to_string();
        assert!(e.contains("r5"), "{e}");
    }

    #[test]
    fn rejects_branch_out_of_range() {
        let p = method(vec![Instr::Goto(99)], 1);
        assert!(verify_program(&p).is_err());
    }

    #[test]
    fn rejects_fall_off_end() {
        let p = method(vec![Instr::Const(0, 1)], 1);
        let e = verify_program(&p).unwrap_err().to_string();
        assert!(e.contains("fall off"), "{e}");
    }

    #[test]
    fn rejects_bad_invoke_arity() {
        let p = method(
            vec![
                Instr::Invoke {
                    mref: MRef {
                        class: ClassId(0),
                        method: MethodId(0),
                    },
                    ret: None,
                    args: vec![0, 0],
                },
                Instr::Return(None),
            ],
            1,
        );
        assert!(verify_program(&p).is_err(), "main takes 0 args");
    }

    #[test]
    fn rejects_bad_static_index() {
        let p = method(
            vec![Instr::GetStatic(0, ClassId(0), 7), Instr::Return(None)],
            1,
        );
        assert!(verify_program(&p).is_err());
    }

    #[test]
    fn ccstart_ccstop_are_legal_anywhere_but_not_terminal() {
        let p = method(vec![Instr::CcStart(0), Instr::Return(None)], 1);
        verify_program(&p).unwrap();
        let p2 = method(vec![Instr::CcStop(0)], 1);
        assert!(verify_program(&p2).is_err(), "ccstop cannot be terminal");
    }
}
