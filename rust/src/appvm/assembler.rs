//! DroidVM textual assembler.
//!
//! The three evaluation apps (`apps/`) and the examples are written in
//! this assembly, keeping their method/call structure as legible as the
//! paper's Figure 5. Two-pass: signatures first (so forward references
//! to classes/methods resolve), then bodies.
//!
//! ```text
//! # comment
//! class VirusScanner app
//!   static total
//!   field sigs
//!   method main nargs=0 regs=8 pinned
//!     invokev VirusScanner.scan
//!     retv
//!   end
//!   method read nargs=3 regs=4 native=fs.read natstate
//! end
//! ```
//!
//! Instruction syntax (registers are `rN`; branch targets `@label`,
//! labels are lines ending in `:`):
//!
//! `const rD 42` · `constf rD 3.5` · `move rD rS` ·
//! `add|sub|mul|div|rem|and|or|xor|shl|shr rD rA rB` ·
//! `fadd|fsub|fmul|fdiv rD rA rB` ·
//! `cmplt|cmple|cmpeq|cmpne|cmpge|cmpgt rD rA rB` ·
//! `ifz|ifnz rA @t` · `iflt|ifle|ifeq|ifne|ifge|ifgt rA rB @t` ·
//! `goto @t` · `invoke rD Class.method rA...` · `invokev Class.method rA...` ·
//! `ret rA` · `retv` · `new rD Class` · `getf rD rO Class.field` ·
//! `putf rO Class.field rS` · `gets rD Class.static` · `puts Class.static rS` ·
//! `newarr rD byte|float|val rLen` · `aget rD rArr rIdx` ·
//! `aput rArr rIdx rS` · `len rD rArr` · `i2f rD rS` · `f2i rD rS` ·
//! `ccstart N` · `ccstop N` · `nop`

use std::collections::HashMap;

use super::bytecode::{ArrKind, ClassId, CmpOp, FloatOp, Instr, IntOp, MRef};
use super::class::{ClassDef, MethodDef, Program};
use super::natives::NativeRegistry;
use super::zygote::install_system_classes;
use crate::error::{CloneCloudError, Result};

fn perr(line_no: usize, msg: impl Into<String>) -> CloneCloudError {
    CloneCloudError::program(format!("line {}: {}", line_no + 1, msg.into()))
}

/// Assemble a program from source. System (Zygote + array) classes are
/// installed automatically.
pub fn assemble(src: &str) -> Result<Program> {
    let lines: Vec<(usize, String)> = src
        .lines()
        .enumerate()
        .map(|(i, l)| {
            let no_comment = match l.find('#') {
                Some(p) => &l[..p],
                None => l,
            };
            (i, no_comment.trim().to_string())
        })
        .filter(|(_, l)| !l.is_empty())
        .collect();

    // ---- Pass 1: class/method signatures -------------------------------
    let mut program = Program::new();
    install_system_classes(&mut program);

    #[derive(Debug)]
    struct PendingBody {
        class: String,
        method: String,
        lines: Vec<(usize, String)>,
    }
    let mut bodies: Vec<PendingBody> = Vec::new();

    let mut i = 0;
    while i < lines.len() {
        let (ln, line) = &lines[i];
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks[0] != "class" {
            return Err(perr(*ln, format!("expected 'class', got '{}'", toks[0])));
        }
        if toks.len() < 2 {
            return Err(perr(*ln, "class needs a name"));
        }
        let cname = toks[1].to_string();
        let system = toks.get(2) == Some(&"system");
        if program.class_id(&cname).is_some() {
            return Err(perr(*ln, format!("duplicate class '{cname}'")));
        }
        let mut class = ClassDef::new(&cname, system);
        i += 1;

        // Class body.
        loop {
            if i >= lines.len() {
                return Err(perr(*ln, format!("class '{cname}' missing 'end'")));
            }
            let (mln, mline) = &lines[i];
            let mtoks: Vec<&str> = mline.split_whitespace().collect();
            match mtoks[0] {
                "end" => {
                    i += 1;
                    break;
                }
                "field" => {
                    if mtoks.len() != 2 {
                        return Err(perr(*mln, "field needs a name"));
                    }
                    class.add_field(mtoks[1]);
                    i += 1;
                }
                "static" => {
                    if mtoks.len() != 2 {
                        return Err(perr(*mln, "static needs a name"));
                    }
                    class.add_static(mtoks[1]);
                    i += 1;
                }
                "method" => {
                    if mtoks.len() < 2 {
                        return Err(perr(*mln, "method needs a name"));
                    }
                    let mname = mtoks[1].to_string();
                    let mut nargs = 0usize;
                    let mut nregs = 0usize;
                    let mut pinned = false;
                    let mut natstate = false;
                    let mut native: Option<String> = None;
                    for t in &mtoks[2..] {
                        if let Some(v) = t.strip_prefix("nargs=") {
                            nargs = v
                                .parse()
                                .map_err(|_| perr(*mln, "bad nargs"))?;
                        } else if let Some(v) = t.strip_prefix("regs=") {
                            nregs = v.parse().map_err(|_| perr(*mln, "bad regs"))?;
                        } else if *t == "pinned" {
                            pinned = true;
                        } else if *t == "natstate" {
                            natstate = true;
                        } else if let Some(v) = t.strip_prefix("native=") {
                            native = Some(v.to_string());
                        } else {
                            return Err(perr(*mln, format!("unknown method attr '{t}'")));
                        }
                    }
                    // main is always pinned (Property 1).
                    if mname == "main" {
                        pinned = true;
                    }
                    let native_id = match &native {
                        Some(n) => {
                            let reg = NativeRegistry::standard();
                            let id = reg
                                .lookup(n)
                                .ok_or_else(|| perr(*mln, format!("unknown native '{n}'")))?;
                            let def = reg.def(id);
                            if def.nargs != nargs {
                                return Err(perr(
                                    *mln,
                                    format!(
                                        "native '{n}' takes {} args, method declares {nargs}",
                                        def.nargs
                                    ),
                                ));
                            }
                            // Pinned-ness flows from the native definition.
                            if def.pinned {
                                pinned = true;
                            }
                            Some(id)
                        }
                        None => None,
                    };
                    let is_native = native_id.is_some();
                    class.add_method(MethodDef {
                        name: mname.clone(),
                        nargs,
                        nregs: nregs.max(nargs),
                        code: Vec::new(),
                        native: native_id,
                        pinned,
                        native_state: natstate,
                        migration_point: None,
                    });
                    i += 1;
                    if !is_native {
                        // Collect body lines until 'end'.
                        let mut body = Vec::new();
                        loop {
                            if i >= lines.len() {
                                return Err(perr(*mln, format!("method '{mname}' missing 'end'")));
                            }
                            let (bln, bline) = &lines[i];
                            if bline == "end" {
                                i += 1;
                                break;
                            }
                            body.push((*bln, bline.clone()));
                            i += 1;
                        }
                        bodies.push(PendingBody {
                            class: cname.clone(),
                            method: mname,
                            lines: body,
                        });
                    }
                }
                other => return Err(perr(*mln, format!("unexpected '{other}' in class body"))),
            }
        }
        program.add_class(class);
    }

    // ---- Pass 2: assemble bodies ---------------------------------------
    for body in bodies {
        let code = assemble_body(&program, &body.lines)?;
        let mref = program.resolve(&body.class, &body.method)?;
        program.method_mut(mref).code = code;
    }
    Ok(program)
}

fn parse_reg(tok: &str, ln: usize) -> Result<u8> {
    tok.strip_prefix('r')
        .and_then(|s| s.parse::<u8>().ok())
        .ok_or_else(|| perr(ln, format!("expected register, got '{tok}'")))
}

fn parse_label(tok: &str, ln: usize) -> Result<String> {
    tok.strip_prefix('@')
        .map(|s| s.to_string())
        .ok_or_else(|| perr(ln, format!("expected @label, got '{tok}'")))
}

fn resolve_class(p: &Program, name: &str, ln: usize) -> Result<ClassId> {
    p.class_id(name)
        .ok_or_else(|| perr(ln, format!("unknown class '{name}'")))
}

fn resolve_method(p: &Program, qualified: &str, ln: usize) -> Result<MRef> {
    let (c, m) = qualified
        .split_once('.')
        .ok_or_else(|| perr(ln, format!("expected Class.method, got '{qualified}'")))?;
    p.resolve(c, m).map_err(|_| {
        perr(ln, format!("unknown method '{qualified}'"))
    })
}

fn split_qualified<'a>(tok: &'a str, ln: usize) -> Result<(&'a str, &'a str)> {
    tok.split_once('.')
        .ok_or_else(|| perr(ln, format!("expected Class.name, got '{tok}'")))
}

fn assemble_body(p: &Program, lines: &[(usize, String)]) -> Result<Vec<Instr>> {
    // Pass A: label positions (labels don't occupy a slot).
    let mut labels: HashMap<String, u32> = HashMap::new();
    let mut pc = 0u32;
    for (ln, line) in lines {
        if let Some(name) = line.strip_suffix(':') {
            if labels.insert(name.to_string(), pc).is_some() {
                return Err(perr(*ln, format!("duplicate label '{name}'")));
            }
        } else {
            pc += 1;
        }
    }

    // Pass B: instructions.
    let int_ops: HashMap<&str, IntOp> = [
        ("add", IntOp::Add),
        ("sub", IntOp::Sub),
        ("mul", IntOp::Mul),
        ("div", IntOp::Div),
        ("rem", IntOp::Rem),
        ("and", IntOp::And),
        ("or", IntOp::Or),
        ("xor", IntOp::Xor),
        ("shl", IntOp::Shl),
        ("shr", IntOp::Shr),
    ]
    .into_iter()
    .collect();
    let float_ops: HashMap<&str, FloatOp> = [
        ("fadd", FloatOp::Add),
        ("fsub", FloatOp::Sub),
        ("fmul", FloatOp::Mul),
        ("fdiv", FloatOp::Div),
    ]
    .into_iter()
    .collect();
    let cmp_ops: HashMap<&str, CmpOp> = [
        ("lt", CmpOp::Lt),
        ("le", CmpOp::Le),
        ("eq", CmpOp::Eq),
        ("ne", CmpOp::Ne),
        ("ge", CmpOp::Ge),
        ("gt", CmpOp::Gt),
    ]
    .into_iter()
    .collect();

    let lbl = |labels: &HashMap<String, u32>, name: &str, ln: usize| -> Result<u32> {
        labels
            .get(name)
            .copied()
            .ok_or_else(|| perr(ln, format!("unknown label '@{name}'")))
    };

    let mut code = Vec::new();
    for (ln, line) in lines {
        if line.ends_with(':') {
            continue;
        }
        let t: Vec<&str> = line.split_whitespace().collect();
        let op = t[0];
        let need = |n: usize| -> Result<()> {
            if t.len() != n + 1 {
                Err(perr(*ln, format!("'{op}' takes {n} operands, got {}", t.len() - 1)))
            } else {
                Ok(())
            }
        };
        let instr = if let Some(io) = int_ops.get(op) {
            need(3)?;
            Instr::IntBin(*io, parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?, parse_reg(t[3], *ln)?)
        } else if let Some(fo) = float_ops.get(op) {
            need(3)?;
            Instr::FloatBin(*fo, parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?, parse_reg(t[3], *ln)?)
        } else if let Some(co) = op.strip_prefix("cmp").and_then(|s| cmp_ops.get(s)) {
            need(3)?;
            Instr::Cmp(*co, parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?, parse_reg(t[3], *ln)?)
        } else if op != "ifz" && op != "ifnz" && op.len() == 4 && op.starts_with("if") {
            let co = cmp_ops
                .get(&op[2..])
                .ok_or_else(|| perr(*ln, format!("unknown op '{op}'")))?;
            need(3)?;
            Instr::IfCmp(
                *co,
                parse_reg(t[1], *ln)?,
                parse_reg(t[2], *ln)?,
                lbl(&labels, &parse_label(t[3], *ln)?, *ln)?,
            )
        } else {
            match op {
                "nop" => {
                    need(0)?;
                    Instr::Nop
                }
                "const" => {
                    need(2)?;
                    let v: i64 = t[2]
                        .parse()
                        .map_err(|_| perr(*ln, format!("bad int '{}'", t[2])))?;
                    Instr::Const(parse_reg(t[1], *ln)?, v)
                }
                "constf" => {
                    need(2)?;
                    let v: f64 = t[2]
                        .parse()
                        .map_err(|_| perr(*ln, format!("bad float '{}'", t[2])))?;
                    Instr::ConstF(parse_reg(t[1], *ln)?, v)
                }
                "move" => {
                    need(2)?;
                    Instr::Move(parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?)
                }
                "ifz" => {
                    need(2)?;
                    Instr::IfZ(
                        parse_reg(t[1], *ln)?,
                        lbl(&labels, &parse_label(t[2], *ln)?, *ln)?,
                    )
                }
                "ifnz" => {
                    need(2)?;
                    Instr::IfNZ(
                        parse_reg(t[1], *ln)?,
                        lbl(&labels, &parse_label(t[2], *ln)?, *ln)?,
                    )
                }
                "goto" => {
                    need(1)?;
                    Instr::Goto(lbl(&labels, &parse_label(t[1], *ln)?, *ln)?)
                }
                "invoke" => {
                    if t.len() < 3 {
                        return Err(perr(*ln, "invoke rD Class.method [args...]"));
                    }
                    let ret = parse_reg(t[1], *ln)?;
                    let mref = resolve_method(p, t[2], *ln)?;
                    let args = t[3..]
                        .iter()
                        .map(|a| parse_reg(a, *ln))
                        .collect::<Result<Vec<_>>>()?;
                    Instr::Invoke {
                        mref,
                        ret: Some(ret),
                        args,
                    }
                }
                "invokev" => {
                    if t.len() < 2 {
                        return Err(perr(*ln, "invokev Class.method [args...]"));
                    }
                    let mref = resolve_method(p, t[1], *ln)?;
                    let args = t[2..]
                        .iter()
                        .map(|a| parse_reg(a, *ln))
                        .collect::<Result<Vec<_>>>()?;
                    Instr::Invoke {
                        mref,
                        ret: None,
                        args,
                    }
                }
                "ret" => {
                    need(1)?;
                    Instr::Return(Some(parse_reg(t[1], *ln)?))
                }
                "retv" => {
                    need(0)?;
                    Instr::Return(None)
                }
                "new" => {
                    need(2)?;
                    Instr::New(parse_reg(t[1], *ln)?, resolve_class(p, t[2], *ln)?)
                }
                "getf" => {
                    need(3)?;
                    let (cn, fnm) = split_qualified(t[3], *ln)?;
                    let cid = resolve_class(p, cn, *ln)?;
                    let fid = p
                        .class(cid)
                        .field_id(fnm)
                        .ok_or_else(|| perr(*ln, format!("unknown field '{}'", t[3])))?;
                    Instr::GetField(parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?, fid)
                }
                "putf" => {
                    need(3)?;
                    let (cn, fnm) = split_qualified(t[2], *ln)?;
                    let cid = resolve_class(p, cn, *ln)?;
                    let fid = p
                        .class(cid)
                        .field_id(fnm)
                        .ok_or_else(|| perr(*ln, format!("unknown field '{}'", t[2])))?;
                    Instr::PutField(parse_reg(t[1], *ln)?, fid, parse_reg(t[3], *ln)?)
                }
                "gets" => {
                    need(2)?;
                    let (cn, snm) = split_qualified(t[2], *ln)?;
                    let cid = resolve_class(p, cn, *ln)?;
                    let sid = p
                        .class(cid)
                        .static_id(snm)
                        .ok_or_else(|| perr(*ln, format!("unknown static '{}'", t[2])))?;
                    Instr::GetStatic(parse_reg(t[1], *ln)?, cid, sid)
                }
                "puts" => {
                    need(2)?;
                    let (cn, snm) = split_qualified(t[1], *ln)?;
                    let cid = resolve_class(p, cn, *ln)?;
                    let sid = p
                        .class(cid)
                        .static_id(snm)
                        .ok_or_else(|| perr(*ln, format!("unknown static '{}'", t[1])))?;
                    Instr::PutStatic(cid, sid, parse_reg(t[2], *ln)?)
                }
                "newarr" => {
                    need(3)?;
                    let kind = match t[2] {
                        "byte" => ArrKind::Byte,
                        "float" => ArrKind::Float,
                        "val" => ArrKind::Val,
                        other => return Err(perr(*ln, format!("bad array kind '{other}'"))),
                    };
                    Instr::NewArray(parse_reg(t[1], *ln)?, kind, parse_reg(t[3], *ln)?)
                }
                "aget" => {
                    need(3)?;
                    Instr::ArrGet(parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?, parse_reg(t[3], *ln)?)
                }
                "aput" => {
                    need(3)?;
                    Instr::ArrPut(parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?, parse_reg(t[3], *ln)?)
                }
                "len" => {
                    need(2)?;
                    Instr::ArrLen(parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?)
                }
                "i2f" => {
                    need(2)?;
                    Instr::IntToFloat(parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?)
                }
                "f2i" => {
                    need(2)?;
                    Instr::FloatToInt(parse_reg(t[1], *ln)?, parse_reg(t[2], *ln)?)
                }
                "ccstart" => {
                    need(1)?;
                    let v: u32 = t[1].parse().map_err(|_| perr(*ln, "bad point id"))?;
                    Instr::CcStart(v)
                }
                "ccstop" => {
                    need(1)?;
                    let v: u32 = t[1].parse().map_err(|_| perr(*ln, "bad point id"))?;
                    Instr::CcStop(v)
                }
                other => return Err(perr(*ln, format!("unknown op '{other}'"))),
            }
        };
        code.push(instr);
    }
    Ok(code)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIB: &str = r#"
# fib(n) benchmark program
class Fib app
  static result
  method main nargs=0 regs=4
    const r0 10
    invoke r1 Fib.fib r0
    puts Fib.result r1
    retv
  end
  method fib nargs=1 regs=6
    const r1 2
    ifge r0 r1 @recurse
    ret r0
  recurse:
    const r2 1
    sub r3 r0 r2
    invoke r4 Fib.fib r3
    const r2 2
    sub r3 r0 r2
    invoke r5 Fib.fib r3
    add r3 r4 r5
    ret r3
  end
end
"#;

    #[test]
    fn assembles_fib() {
        let p = assemble(FIB).unwrap();
        let fib = p.resolve("Fib", "fib").unwrap();
        assert_eq!(p.method(fib).nargs, 1);
        assert!(p.method(fib).code.len() > 5);
        let main = p.entry().unwrap();
        assert!(p.method(main).pinned, "main auto-pinned");
    }

    #[test]
    fn labels_resolve_forward_and_backward() {
        let src = r#"
class L app
  method main nargs=0 regs=2
    goto @fwd
  back:
    retv
  fwd:
    goto @back
  end
end
"#;
        let p = assemble(src).unwrap();
        let m = p.entry().unwrap();
        assert_eq!(
            p.method(m).code,
            vec![Instr::Goto(2), Instr::Return(None), Instr::Goto(1)]
        );
    }

    #[test]
    fn native_methods_resolve_against_registry() {
        let src = r#"
class N app
  method main nargs=0 regs=2
    invoke r0 N.count
    retv
  end
  method count nargs=0 regs=0 native=fs.count
end
"#;
        let p = assemble(src).unwrap();
        let m = p.resolve("N", "count").unwrap();
        assert!(p.method(m).is_native());
        assert!(!p.method(m).pinned);
    }

    #[test]
    fn pinned_flows_from_native_def() {
        let src = r#"
class N app
  method main nargs=0 regs=1
    retv
  end
  method show nargs=1 regs=1 native=ui.show
end
"#;
        let p = assemble(src).unwrap();
        let m = p.resolve("N", "show").unwrap();
        assert!(p.method(m).pinned, "ui native is V_M");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let src = "class X app\n  method main nargs=0 regs=1\n    bogus r1\n  end\nend\n";
        let e = assemble(src).unwrap_err().to_string();
        assert!(e.contains("line 3"), "{e}");
        assert!(e.contains("bogus"), "{e}");
    }

    #[test]
    fn rejects_unknown_native_and_bad_arity() {
        let src = "class X app\n  method main nargs=0 regs=1\n    retv\n  end\n  method f nargs=0 regs=0 native=no.such\nend\n";
        assert!(assemble(src).is_err());
        let src2 = "class X app\n  method main nargs=0 regs=1\n    retv\n  end\n  method f nargs=1 regs=1 native=fs.count\nend\n";
        assert!(assemble(src2).is_err(), "fs.count takes 0 args");
    }

    #[test]
    fn rejects_duplicate_class_and_label() {
        let src = "class X app\n  method main nargs=0 regs=1\n    retv\n  end\nend\nclass X app\nend\n";
        assert!(assemble(src).is_err());
        let src2 = "class X app\n  method main nargs=0 regs=1\n  a:\n  a:\n    retv\n  end\nend\n";
        assert!(assemble(src2).is_err());
    }

    #[test]
    fn natstate_attribute_recorded() {
        let src = r#"
class R app
  method main nargs=0 regs=1
    retv
  end
  method read nargs=3 regs=3 native=fs.read natstate
  method size nargs=1 regs=1 native=fs.size natstate
end
"#;
        let p = assemble(src).unwrap();
        assert!(p.method(p.resolve("R", "read").unwrap()).native_state);
        assert!(p.method(p.resolve("R", "size").unwrap()).native_state);
    }
}
