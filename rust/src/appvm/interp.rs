//! The DroidVM interpreter (execution tier 0).
//!
//! Executes one thread until it completes, faults, or reaches a
//! CloneCloud migration/reintegration point (`CcStart`/`CcStop`, the
//! instructions the partitioner's rewriter inserts). The interpreter
//! itself is policy-free: it *reports* partition points to the driver
//! (`exec::`), which consults the policy engine and the migrator —
//! mirroring the prototype's split between the modified Dalvik
//! interpreter and the migrator thread (paper §5).
//!
//! The per-instruction semantics live in [`super::ops::step_one`],
//! shared with the direct-threaded tier-1 engine ([`super::tier1`]);
//! this module is the switch-dispatch driver around it — the only tier
//! on the phone side and the clone's ablation baseline
//! (`exec_tier = "interp"`).
//!
//! Entry/exit hooks on app methods feed the dynamic profiler (§3.2).

use super::bytecode::MRef;
use super::ops;
use super::process::Process;
use super::value::Value;
use crate::config::CostParams;
use crate::error::Result;

/// Why `run_thread` returned.
#[derive(Debug, Clone, PartialEq)]
pub enum RunExit {
    /// The thread ran to completion; value is `main`'s return (if any).
    Completed(Option<Value>),
    /// Hit a `CcStart(point)` — a migration point. The pc has advanced
    /// past the instruction; re-entering `run_thread` continues locally.
    MigrationPoint { point: u32 },
    /// Hit a `CcStop(point)` — a reintegration point; same continuation
    /// semantics.
    ReintegrationPoint { point: u32 },
    /// Ran out of fuel (instruction budget) — a test/diagnostic guard.
    OutOfFuel,
}

/// Observation hooks for app-method entry/exit (profiling). Native
/// calls execute inline but are reported via `on_native` so the profiler
/// can count call-site traffic (used by the class-granularity baseline's
/// RPC pricing).
pub trait ExecHooks {
    fn on_entry(&mut self, _p: &mut Process, _tid: u32, _mref: MRef) {}
    fn on_exit(&mut self, _p: &mut Process, _tid: u32, _mref: MRef) {}
    fn on_native(&mut self, _p: &mut Process, _tid: u32, _caller: MRef, _callee: MRef) {}
}

/// No-op hooks.
pub struct NoHooks;
impl ExecHooks for NoHooks {}

/// Interpreter entry: run thread `tid` of `p` until an exit condition.
/// `fuel` bounds the number of executed instructions (use `u64::MAX`
/// for production runs).
pub fn run_thread<H: ExecHooks>(
    p: &mut Process,
    tid: u32,
    hooks: &mut H,
    fuel: u64,
) -> Result<RunExit> {
    let costs: CostParams = p.env_costs();
    let instr_cost = p.device.scale_us(costs.instr_us);
    // One Arc clone per run lets every fetch borrow the instruction
    // in place instead of cloning it out of the method body.
    let program = p.program.clone();
    let mut spent: u64 = 0;

    loop {
        if spent >= fuel {
            return Ok(RunExit::OutOfFuel);
        }
        match ops::step_one(p, &program, tid, hooks, &costs, instr_cost)? {
            Some(exit) => return Ok(exit),
            None => spent += 1,
        }
    }
}

impl Process {
    /// Cost parameters used by the interpreter. Kept on the process's
    /// environment; defaulted here (overridable per-run via exec::).
    pub fn env_costs(&self) -> CostParams {
        self.cost_params.clone().unwrap_or_default()
    }
}
