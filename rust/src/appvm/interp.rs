//! The DroidVM interpreter.
//!
//! Executes one thread until it completes, faults, or reaches a
//! CloneCloud migration/reintegration point (`CcStart`/`CcStop`, the
//! instructions the partitioner's rewriter inserts). The interpreter
//! itself is policy-free: it *reports* partition points to the driver
//! (`exec::`), which consults the policy engine and the migrator —
//! mirroring the prototype's split between the modified Dalvik
//! interpreter and the migrator thread (paper §5).
//!
//! Entry/exit hooks on app methods feed the dynamic profiler (§3.2).

use super::bytecode::{eval_cmp_f, eval_cmp_i, eval_float, eval_int, ArrKind, Instr, MRef};
use super::natives::{NativeCtx, NativeRegistry};
use super::process::Process;
use super::thread::{Frame, ThreadStatus};
use super::value::{ObjBody, Object, Value};
use crate::config::CostParams;
use crate::error::{CloneCloudError, Result};

/// Why `run_thread` returned.
#[derive(Debug, Clone, PartialEq)]
pub enum RunExit {
    /// The thread ran to completion; value is `main`'s return (if any).
    Completed(Option<Value>),
    /// Hit a `CcStart(point)` — a migration point. The pc has advanced
    /// past the instruction; re-entering `run_thread` continues locally.
    MigrationPoint { point: u32 },
    /// Hit a `CcStop(point)` — a reintegration point; same continuation
    /// semantics.
    ReintegrationPoint { point: u32 },
    /// Ran out of fuel (instruction budget) — a test/diagnostic guard.
    OutOfFuel,
}

/// Observation hooks for app-method entry/exit (profiling). Native
/// calls execute inline but are reported via `on_native` so the profiler
/// can count call-site traffic (used by the class-granularity baseline's
/// RPC pricing).
pub trait ExecHooks {
    fn on_entry(&mut self, _p: &mut Process, _tid: u32, _mref: MRef) {}
    fn on_exit(&mut self, _p: &mut Process, _tid: u32, _mref: MRef) {}
    fn on_native(&mut self, _p: &mut Process, _tid: u32, _caller: MRef, _callee: MRef) {}
}

/// No-op hooks.
pub struct NoHooks;
impl ExecHooks for NoHooks {}

/// Interpreter entry: run thread `tid` of `p` until an exit condition.
/// `fuel` bounds the number of executed instructions (use `u64::MAX`
/// for production runs).
pub fn run_thread<H: ExecHooks>(
    p: &mut Process,
    tid: u32,
    hooks: &mut H,
    fuel: u64,
) -> Result<RunExit> {
    let costs: CostParams = p.env_costs();
    let instr_cost = p.device.scale_us(costs.instr_us);
    let mut spent: u64 = 0;

    loop {
        if spent >= fuel {
            return Ok(RunExit::OutOfFuel);
        }
        let t = p.thread(tid)?;
        match t.status {
            ThreadStatus::Finished => return Ok(RunExit::Completed(None)),
            ThreadStatus::Suspended | ThreadStatus::Migrated => {
                return Err(CloneCloudError::vm(format!(
                    "thread {tid} not runnable ({:?})",
                    t.status
                )))
            }
            ThreadStatus::Runnable => {}
        }

        // Fetch.
        let frame = p
            .thread(tid)?
            .current_frame()
            .ok_or_else(|| CloneCloudError::vm("runnable thread with no frames"))?;
        let mref = frame.method;
        let pc = frame.pc;
        let method = p.program.method(mref);
        if pc >= method.code.len() {
            return Err(CloneCloudError::vm(format!(
                "pc {pc} past end of {}",
                p.program.method_name(mref)
            )));
        }
        let instr = method.code[pc].clone();

        // Charge and advance.
        p.clock.charge_us(instr_cost);
        p.metrics.instrs += 1;
        spent += 1;
        {
            let t = p.thread_mut(tid)?;
            t.cpu_us += instr_cost;
            t.current_frame_mut().unwrap().pc = pc + 1;
        }

        // Execute.
        match instr {
            Instr::Nop => {}
            Instr::Const(d, v) => set_reg(p, tid, d, Value::Int(v))?,
            Instr::ConstF(d, v) => set_reg(p, tid, d, Value::Float(v))?,
            Instr::Move(d, s) => {
                let v = get_reg(p, tid, s)?;
                set_reg(p, tid, d, v)?;
            }
            Instr::IntBin(op, d, a, b) => {
                let (x, y) = (int_reg(p, tid, a)?, int_reg(p, tid, b)?);
                let v = eval_int(op, x, y)
                    .ok_or_else(|| CloneCloudError::vm("division by zero"))?;
                set_reg(p, tid, d, Value::Int(v))?;
            }
            Instr::FloatBin(op, d, a, b) => {
                let (x, y) = (float_reg(p, tid, a)?, float_reg(p, tid, b)?);
                set_reg(p, tid, d, Value::Float(eval_float(op, x, y)))?;
            }
            Instr::Cmp(op, d, a, b) => {
                let va = get_reg(p, tid, a)?;
                let vb = get_reg(p, tid, b)?;
                let r = cmp_values(op, va, vb)?;
                set_reg(p, tid, d, Value::Int(r as i64))?;
            }
            Instr::IfZ(r, target) => {
                if !get_reg(p, tid, r)?.is_truthy() {
                    jump(p, tid, target)?;
                }
            }
            Instr::IfNZ(r, target) => {
                if get_reg(p, tid, r)?.is_truthy() {
                    jump(p, tid, target)?;
                }
            }
            Instr::IfCmp(op, a, b, target) => {
                let va = get_reg(p, tid, a)?;
                let vb = get_reg(p, tid, b)?;
                if cmp_values(op, va, vb)? {
                    jump(p, tid, target)?;
                }
            }
            Instr::Goto(target) => jump(p, tid, target)?,
            Instr::Invoke { mref: callee, ret, args } => {
                p.metrics.invokes += 1;
                let callee_def = p.program.method(callee);
                let nargs = callee_def.nargs;
                if args.len() != nargs {
                    return Err(CloneCloudError::vm(format!(
                        "{} expects {nargs} args, got {}",
                        p.program.method_name(callee),
                        args.len()
                    )));
                }
                let mut argv = Vec::with_capacity(args.len());
                for &r in &args {
                    argv.push(get_reg(p, tid, r)?);
                }
                if let Some(nid) = callee_def.native {
                    // Natives execute inline (treated as part of the
                    // calling method's body by the profiler, §3.2).
                    p.metrics.native_calls += 1;
                    let reg = NativeRegistry::standard();
                    let result = {
                        let Process {
                            ref mut heap,
                            ref mut clock,
                            ref device,
                            location,
                            ref mut env,
                            array_class,
                            allow_pinned,
                            ..
                        } = *p;
                        let mut ctx = NativeCtx {
                            heap,
                            clock,
                            device,
                            costs: &costs,
                            location,
                            env,
                            array_class,
                            allow_pinned,
                        };
                        reg.call(nid, &mut ctx, &argv)?
                    };
                    if let Some(d) = ret {
                        set_reg(p, tid, d, result)?;
                    }
                    hooks.on_native(p, tid, mref, callee);
                } else {
                    let nregs = callee_def.nregs;
                    let mut frame = Frame::new(callee, nregs, ret);
                    frame.regs[..argv.len()].copy_from_slice(&argv);
                    p.thread_mut(tid)?.frames.push(frame);
                    hooks.on_entry(p, tid, callee);
                }
            }
            Instr::Return(src) => {
                let rv = match src {
                    Some(r) => Some(get_reg(p, tid, r)?),
                    None => None,
                };
                let finished_frame = p
                    .thread_mut(tid)?
                    .frames
                    .pop()
                    .ok_or_else(|| CloneCloudError::vm("return with no frame"))?;
                hooks.on_exit(p, tid, finished_frame.method);
                let t = p.thread_mut(tid)?;
                if t.frames.is_empty() {
                    t.status = ThreadStatus::Finished;
                    return Ok(RunExit::Completed(rv));
                }
                if let (Some(dst), Some(v)) = (finished_frame.ret_reg, rv) {
                    set_reg(p, tid, dst, v)?;
                }
            }
            Instr::New(d, class) => {
                let nfields = p.program.class(class).fields.len();
                p.metrics.allocations += 1;
                let id = p.heap.alloc(Object::new_fields(class, nfields));
                set_reg(p, tid, d, Value::Ref(id))?;
            }
            Instr::GetField(d, o, idx) => {
                let oid = ref_reg(p, tid, o)?;
                let obj = p.heap.get(oid)?;
                let v = match &obj.body {
                    ObjBody::Fields(fs) => *fs.get(idx as usize).ok_or_else(|| {
                        CloneCloudError::vm(format!("field index {idx} out of range"))
                    })?,
                    _ => return Err(CloneCloudError::vm("getfield on array")),
                };
                set_reg(p, tid, d, v)?;
            }
            Instr::PutField(o, idx, s) => {
                let v = get_reg(p, tid, s)?;
                let oid = ref_reg(p, tid, o)?;
                let obj = p.heap.get_mut(oid)?;
                match &mut obj.body {
                    ObjBody::Fields(fs) => {
                        let slot = fs.get_mut(idx as usize).ok_or_else(|| {
                            CloneCloudError::vm(format!("field index {idx} out of range"))
                        })?;
                        *slot = v;
                    }
                    _ => return Err(CloneCloudError::vm("putfield on array")),
                }
            }
            Instr::GetStatic(d, class, idx) => {
                let v = *p
                    .statics
                    .get(class.0 as usize)
                    .and_then(|s| s.get(idx as usize))
                    .ok_or_else(|| CloneCloudError::vm("static index out of range"))?;
                set_reg(p, tid, d, v)?;
            }
            Instr::PutStatic(class, idx, s) => {
                let v = get_reg(p, tid, s)?;
                // Through the statics write barrier: stamps the slot's
                // mutation epoch for delta captures.
                p.put_static(class.0 as usize, idx as usize, v)?;
            }
            Instr::NewArray(d, kind, len_reg) => {
                let len = int_reg(p, tid, len_reg)?;
                if len < 0 {
                    return Err(CloneCloudError::vm("negative array length"));
                }
                p.metrics.allocations += 1;
                let class = p.array_class;
                let id = match kind {
                    ArrKind::Byte => p.heap.alloc_byte_array(class, vec![0; len as usize]),
                    ArrKind::Float => p.heap.alloc_float_array(class, vec![0.0; len as usize]),
                    ArrKind::Val => p.heap.alloc_ref_array(class, len as usize),
                };
                set_reg(p, tid, d, Value::Ref(id))?;
            }
            Instr::ArrGet(d, arr, idx) => {
                let oid = ref_reg(p, tid, arr)?;
                let i = int_reg(p, tid, idx)? as usize;
                let v = match &p.heap.get(oid)?.body {
                    ObjBody::ByteArray(b) => Value::Int(*b.get(i).ok_or_else(oob)? as i64),
                    ObjBody::FloatArray(f) => Value::Float(*f.get(i).ok_or_else(oob)? as f64),
                    ObjBody::RefArray(v) => *v.get(i).ok_or_else(oob)?,
                    ObjBody::Fields(_) => return Err(CloneCloudError::vm("arrget on object")),
                };
                set_reg(p, tid, d, v)?;
            }
            Instr::ArrPut(arr, idx, src) => {
                let v = get_reg(p, tid, src)?;
                let oid = ref_reg(p, tid, arr)?;
                let i = int_reg(p, tid, idx)? as usize;
                match &mut p.heap.get_mut(oid)?.body {
                    ObjBody::ByteArray(b) => {
                        let slot = b.get_mut(i).ok_or_else(oob)?;
                        *slot = v.as_int().ok_or_else(|| {
                            CloneCloudError::vm("byte array stores require ints")
                        })? as u8;
                    }
                    ObjBody::FloatArray(f) => {
                        let slot = f.get_mut(i).ok_or_else(oob)?;
                        *slot = v.as_float().ok_or_else(|| {
                            CloneCloudError::vm("float array stores require numbers")
                        })? as f32;
                    }
                    ObjBody::RefArray(rv) => {
                        let slot = rv.get_mut(i).ok_or_else(oob)?;
                        *slot = v;
                    }
                    ObjBody::Fields(_) => return Err(CloneCloudError::vm("arrput on object")),
                }
            }
            Instr::ArrLen(d, arr) => {
                let oid = ref_reg(p, tid, arr)?;
                let len = match &p.heap.get(oid)?.body {
                    ObjBody::ByteArray(b) => b.len(),
                    ObjBody::FloatArray(f) => f.len(),
                    ObjBody::RefArray(v) => v.len(),
                    ObjBody::Fields(_) => return Err(CloneCloudError::vm("arrlen on object")),
                };
                set_reg(p, tid, d, Value::Int(len as i64))?;
            }
            Instr::IntToFloat(d, s) => {
                let v = int_reg(p, tid, s)?;
                set_reg(p, tid, d, Value::Float(v as f64))?;
            }
            Instr::FloatToInt(d, s) => {
                let v = float_reg(p, tid, s)?;
                set_reg(p, tid, d, Value::Int(v as i64))?;
            }
            Instr::CcStart(point) => {
                return Ok(RunExit::MigrationPoint { point });
            }
            Instr::CcStop(point) => {
                return Ok(RunExit::ReintegrationPoint { point });
            }
        }
    }
}

fn oob() -> CloneCloudError {
    CloneCloudError::vm("array index out of bounds")
}

impl Process {
    /// Cost parameters used by the interpreter. Kept on the process's
    /// environment; defaulted here (overridable per-run via exec::).
    pub fn env_costs(&self) -> CostParams {
        self.cost_params.clone().unwrap_or_default()
    }
}

fn cmp_values(op: super::bytecode::CmpOp, a: Value, b: Value) -> Result<bool> {
    match (a, b) {
        (Value::Int(x), Value::Int(y)) => Ok(eval_cmp_i(op, x, y)),
        (Value::Null, Value::Null) => Ok(eval_cmp_i(op, 0, 0)),
        (Value::Ref(x), Value::Ref(y)) => Ok(eval_cmp_i(op, x.0 as i64, y.0 as i64)),
        (Value::Ref(_), Value::Null) => Ok(eval_cmp_i(op, 1, 0)),
        (Value::Null, Value::Ref(_)) => Ok(eval_cmp_i(op, 0, 1)),
        _ => {
            let x = a
                .as_float()
                .ok_or_else(|| CloneCloudError::vm("uncomparable values"))?;
            let y = b
                .as_float()
                .ok_or_else(|| CloneCloudError::vm("uncomparable values"))?;
            Ok(eval_cmp_f(op, x, y))
        }
    }
}

fn get_reg(p: &Process, tid: u32, r: u8) -> Result<Value> {
    let f = p
        .thread(tid)?
        .current_frame()
        .ok_or_else(|| CloneCloudError::vm("no frame"))?;
    f.regs
        .get(r as usize)
        .copied()
        .ok_or_else(|| CloneCloudError::vm(format!("register r{r} out of range")))
}

fn set_reg(p: &mut Process, tid: u32, r: u8, v: Value) -> Result<()> {
    let f = p
        .thread_mut(tid)?
        .current_frame_mut()
        .ok_or_else(|| CloneCloudError::vm("no frame"))?;
    let slot = f
        .regs
        .get_mut(r as usize)
        .ok_or_else(|| CloneCloudError::vm(format!("register r{r} out of range")))?;
    *slot = v;
    Ok(())
}

fn int_reg(p: &Process, tid: u32, r: u8) -> Result<i64> {
    get_reg(p, tid, r)?
        .as_int()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not an int")))
}

fn float_reg(p: &Process, tid: u32, r: u8) -> Result<f64> {
    get_reg(p, tid, r)?
        .as_float()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not a float")))
}

fn ref_reg(p: &Process, tid: u32, r: u8) -> Result<super::value::ObjId> {
    get_reg(p, tid, r)?
        .as_ref()
        .ok_or_else(|| CloneCloudError::vm(format!("r{r} is not a reference (null deref?)")))
}

fn jump(p: &mut Process, tid: u32, target: u32) -> Result<()> {
    let f = p
        .thread_mut(tid)?
        .current_frame_mut()
        .ok_or_else(|| CloneCloudError::vm("no frame"))?;
    f.pc = target as usize;
    Ok(())
}
