//! VM values and heap objects.
//!
//! Every heap object carries the per-VM monotonically-increasing object id
//! the paper's object-mapping table is built on (§4.2: MIDs at the mobile
//! device, CIDs at the clone), plus the Zygote bookkeeping used by the
//! transfer optimization of §4.3.

use super::bytecode::ClassId;

/// A per-VM unique object id, assigned from a monotonic counter at object
/// creation. Never reused, unlike raw addresses — this is what lets the
/// migrator distinguish a recycled address from the original object
/// (paper Fig. 8, address 0x22).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjId(pub u64);

/// A VM register / field value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Value {
    Null,
    Int(i64),
    Float(f64),
    Ref(ObjId),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_ref(&self) -> Option<ObjId> {
        match self {
            Value::Ref(r) => Some(*r),
            _ => None,
        }
    }
    pub fn is_truthy(&self) -> bool {
        match self {
            Value::Null => false,
            Value::Int(x) => *x != 0,
            Value::Float(x) => *x != 0.0,
            Value::Ref(_) => true,
        }
    }
}

impl Default for Value {
    fn default() -> Self {
        Value::Null
    }
}

/// Object payload. Byte and float arrays are packed (realistic state
/// sizes for the migration cost model); `Fields` and `RefArray` hold
/// boxed values that may reference other objects.
#[derive(Debug, Clone, PartialEq)]
pub enum ObjBody {
    Fields(Vec<Value>),
    ByteArray(Vec<u8>),
    FloatArray(Vec<f32>),
    RefArray(Vec<Value>),
}

impl ObjBody {
    /// Approximate serialized size in bytes (used for edge annotations in
    /// profile trees and for the transfer cost model).
    pub fn byte_size(&self) -> u64 {
        match self {
            ObjBody::Fields(vs) | ObjBody::RefArray(vs) => 9 * vs.len() as u64,
            ObjBody::ByteArray(b) => b.len() as u64,
            ObjBody::FloatArray(f) => 4 * f.len() as u64,
        }
    }

    /// References held by this object.
    pub fn refs(&self) -> Vec<ObjId> {
        match self {
            ObjBody::Fields(vs) | ObjBody::RefArray(vs) => {
                vs.iter().filter_map(|v| v.as_ref()).collect()
            }
            _ => Vec::new(),
        }
    }
}

/// A heap object.
#[derive(Debug, Clone, PartialEq)]
pub struct Object {
    pub class: ClassId,
    pub body: ObjBody,
    /// Zygote naming: `(class, construction sequence)` for objects created
    /// in the template process (paper §4.3); `None` for app objects.
    pub zygote_seq: Option<u32>,
    /// Mutated since the process was forked from Zygote. Clean Zygote
    /// objects are skipped by the transfer optimization.
    pub dirty: bool,
    /// Heap epoch of the last mutation (stamped by the `Heap::get_mut`
    /// write barrier, and at allocation). Delta migration ships only
    /// objects whose epoch is newer than the negotiated baseline epoch.
    pub epoch: u64,
}

impl Object {
    pub fn new_fields(class: ClassId, n: usize) -> Object {
        Object {
            class,
            body: ObjBody::Fields(vec![Value::Null; n]),
            zygote_seq: None,
            dirty: true,
            epoch: 0,
        }
    }

    pub fn byte_size(&self) -> u64 {
        // Header (class id + object id + flags) + payload.
        16 + self.body.byte_size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Int(3).as_int(), Some(3));
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Float(2.5).as_float(), Some(2.5));
        assert_eq!(Value::Null.as_int(), None);
        assert!(Value::Ref(ObjId(1)).is_truthy());
        assert!(!Value::Null.is_truthy());
        assert!(!Value::Int(0).is_truthy());
    }

    #[test]
    fn body_sizes() {
        assert_eq!(ObjBody::ByteArray(vec![0; 100]).byte_size(), 100);
        assert_eq!(ObjBody::FloatArray(vec![0.0; 10]).byte_size(), 40);
        assert_eq!(ObjBody::Fields(vec![Value::Null; 3]).byte_size(), 27);
    }

    #[test]
    fn refs_extraction() {
        let b = ObjBody::Fields(vec![
            Value::Ref(ObjId(5)),
            Value::Int(1),
            Value::Ref(ObjId(9)),
            Value::Null,
        ]);
        assert_eq!(b.refs(), vec![ObjId(5), ObjId(9)]);
        assert!(ObjBody::ByteArray(vec![1, 2]).refs().is_empty());
    }
}
