//! VM threads: virtual stacks, registers, suspend machinery.
//!
//! Matches the paper's §2/§5 thread model: each thread owns a virtual
//! stack of frames (registers + pc); a per-thread suspend counter is
//! checked at bytecode boundaries so threads stop at *safe points* — the
//! property the migrator relies on to capture consistent state.

use super::bytecode::{MRef, Reg};
use super::value::Value;

/// One virtual stack frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub method: MRef,
    pub regs: Vec<Value>,
    /// Program counter: index of the NEXT instruction to execute.
    pub pc: usize,
    /// Register in the CALLER's frame that receives this frame's return
    /// value (None for void-context calls).
    pub ret_reg: Option<Reg>,
}

impl Frame {
    pub fn new(method: MRef, nregs: usize, ret_reg: Option<Reg>) -> Frame {
        Frame {
            method,
            regs: vec![Value::Null; nregs],
            pc: 0,
            ret_reg,
        }
    }

    /// Root object references held in this frame's registers.
    pub fn ref_roots(&self) -> impl Iterator<Item = super::value::ObjId> + '_ {
        self.regs.iter().filter_map(|v| v.as_ref())
    }
}

/// Thread lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThreadStatus {
    Runnable,
    /// Suspended by the migrator (suspend counter > 0).
    Suspended,
    /// State shipped to the other device; frames here are a tombstone.
    Migrated,
    Finished,
}

/// A VM thread.
#[derive(Debug, Clone)]
pub struct VmThread {
    pub id: u32,
    pub frames: Vec<Frame>,
    pub status: ThreadStatus,
    /// Pending-suspend counter, checked after every instruction (the
    /// Dalvik safe-point mechanism the prototype reuses, §5).
    pub suspend_count: u32,
    /// Virtual time consumed by this thread, µs.
    pub cpu_us: f64,
}

impl VmThread {
    pub fn new(id: u32) -> VmThread {
        VmThread {
            id,
            frames: Vec::new(),
            status: ThreadStatus::Runnable,
            suspend_count: 0,
            cpu_us: 0.0,
        }
    }

    pub fn current_frame(&self) -> Option<&Frame> {
        self.frames.last()
    }

    pub fn current_frame_mut(&mut self) -> Option<&mut Frame> {
        self.frames.last_mut()
    }

    pub fn depth(&self) -> usize {
        self.frames.len()
    }

    /// Request suspension; the interpreter honors it at the next safe
    /// point (instruction boundary).
    pub fn request_suspend(&mut self) {
        self.suspend_count += 1;
    }

    pub fn resume(&mut self) {
        if self.suspend_count > 0 {
            self.suspend_count -= 1;
        }
        if self.suspend_count == 0 && self.status == ThreadStatus::Suspended {
            self.status = ThreadStatus::Runnable;
        }
    }

    /// All object roots across the thread's frames (capture roots).
    pub fn roots(&self) -> Vec<super::value::ObjId> {
        let mut out = Vec::new();
        for f in &self.frames {
            out.extend(f.ref_roots());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::bytecode::{ClassId, MethodId};
    use crate::appvm::value::ObjId;

    fn mref() -> MRef {
        MRef {
            class: ClassId(0),
            method: MethodId(0),
        }
    }

    #[test]
    fn frame_roots() {
        let mut f = Frame::new(mref(), 4, None);
        f.regs[1] = Value::Ref(ObjId(7));
        f.regs[3] = Value::Ref(ObjId(9));
        let roots: Vec<_> = f.ref_roots().collect();
        assert_eq!(roots, vec![ObjId(7), ObjId(9)]);
    }

    #[test]
    fn suspend_resume_counts() {
        let mut t = VmThread::new(0);
        t.request_suspend();
        t.request_suspend();
        t.status = ThreadStatus::Suspended;
        t.resume();
        assert_eq!(t.status, ThreadStatus::Suspended, "count still 1");
        t.resume();
        assert_eq!(t.status, ThreadStatus::Runnable);
    }

    #[test]
    fn thread_roots_span_frames() {
        let mut t = VmThread::new(0);
        let mut f1 = Frame::new(mref(), 2, None);
        f1.regs[0] = Value::Ref(ObjId(1));
        let mut f2 = Frame::new(mref(), 2, None);
        f2.regs[1] = Value::Ref(ObjId(2));
        t.frames.push(f1);
        t.frames.push(f2);
        assert_eq!(t.roots(), vec![ObjId(1), ObjId(2)]);
    }
}
