//! DroidVM: the application-level virtual machine substrate.
//!
//! The paper's prototype modifies Android's Dalvik VM; this module is the
//! equivalent substrate built from scratch (DESIGN.md §2): a register
//! bytecode [`bytecode`], the Method Area [`class`], heap with monotonic
//! object ids and mark-sweep GC [`heap`], threads with safe-point suspend
//! counters [`thread`], the interpreter with migration-point events
//! [`interp`], the native interface [`natives`], the Zygote template
//! [`zygote`], a textual assembler [`assembler`], and a load-time
//! verifier [`verifier`].

pub mod assembler;
pub mod bytecode;
pub mod class;
pub mod heap;
pub mod interp;
pub mod natives;
pub mod process;
pub mod thread;
pub mod value;
pub mod verifier;
pub mod zygote;

pub use bytecode::{ClassId, Instr, MRef, MethodId};
pub use class::{ClassDef, MethodDef, Program};
pub use heap::Heap;
pub use interp::{run_thread, ExecHooks, NoHooks, RunExit};
pub use natives::{ComputeBackend, NativeRegistry, NodeEnv, RustCompute};
pub use process::Process;
pub use thread::{Frame, ThreadStatus, VmThread};
pub use value::{ObjBody, ObjId, Object, Value};
