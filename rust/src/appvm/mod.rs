//! DroidVM: the application-level virtual machine substrate.
//!
//! The paper's prototype modifies Android's Dalvik VM; this module is the
//! equivalent substrate built from scratch (DESIGN.md §2): a register
//! bytecode [`bytecode`], the Method Area [`class`], heap with monotonic
//! object ids and mark-sweep GC [`heap`], threads with safe-point suspend
//! counters [`thread`], the interpreter with migration-point events
//! [`interp`] (single-step semantics shared via [`ops`]), the
//! profile-guided direct-threaded execution tier [`tier1`], the native
//! interface [`natives`], the Zygote template [`zygote`], a textual
//! assembler [`assembler`], and a load-time verifier [`verifier`].
//!
//! # Execution tiers
//!
//! Two engines share one instruction semantics ([`ops::step_one`]):
//!
//! - **Tier 0** ([`interp`]): the switch-dispatch interpreter. The only
//!   tier on the phone side, and the ablation baseline on the clone
//!   (`exec_tier = "interp"`).
//! - **Tier 1** ([`tier1`]): profile-guided direct-threaded dispatch.
//!   When a method crosses a hotness threshold, its `Instr` sequence is
//!   translated once into a pre-decoded [`tier1::Translation`] — operand
//!   registers resolved, branch targets pre-bound to translated-op
//!   indices, adjacent `Const`/`IntBin`/`Goto` runs fused into
//!   superinstructions — cached per `MRef` in a bounded cache that is
//!   invalidated when the program changes. Heavy instructions (invoke,
//!   return, allocation, statics stores, `CcStart`/`CcStop`) bail to the
//!   shared single-step, so there is exactly one implementation of their
//!   semantics.
//!
//! Tier 1 is **bit-identical** to the interpreter by construction and by
//! test (`tests/exec_parity.rs`): same `Value` results, same
//! `clock.charge_us` accounting per instruction, same epoch/page
//! write-barrier stamping through `Heap::get_mut`, same `RunExit` points
//! and fuel semantics, same error strings. The tier may only change how
//! fast the wall clock moves — never what the virtual machine computes.

pub mod assembler;
pub mod bytecode;
pub mod class;
pub mod heap;
pub mod interp;
pub mod natives;
pub(crate) mod ops;
pub mod process;
pub mod thread;
pub mod tier1;
pub mod value;
pub mod verifier;
pub mod zygote;

pub use bytecode::{ClassId, Instr, MRef, MethodId};
pub use class::{ClassDef, MethodDef, Program};
pub use heap::Heap;
pub use interp::{run_thread, ExecHooks, NoHooks, RunExit};
pub use tier1::{ExecTier, Tier1Engine, TierStats};
pub use natives::{ComputeBackend, NativeRegistry, NodeEnv, RustCompute};
pub use process::Process;
pub use thread::{Frame, ThreadStatus, VmThread};
pub use value::{ObjBody, ObjId, Object, Value};
