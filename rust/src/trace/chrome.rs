//! Chrome trace-event JSON export (Perfetto / `chrome://tracing`).
//!
//! Maps the merged session timeline onto the trace-event format's
//! object form: paired begin/end events become complete ("X") slices in
//! virtual-time µs, counters become "C" samples, instants become "i"
//! markers. `pid` is the session id and `tid` the endpoint lane
//! (1 = phone, 2 = clone), so one session renders as a single process
//! with a track per endpoint; thread-name metadata events label the
//! lanes. Wall-clock stamps and trip numbers ride in `args`.

use super::{Endpoint, Event, EventKind};
use crate::util::json::{emit, Json};

fn base_args(ev: &Event) -> Vec<(&'static str, Json)> {
    vec![
        ("trip", Json::from(ev.trip as i64)),
        ("wall_us", Json::from(ev.wall_us as i64)),
    ]
}

fn thread_meta(pid: u64, endpoint: Endpoint) -> Json {
    Json::obj(vec![
        ("ph", "M".into()),
        ("name", "thread_name".into()),
        ("pid", Json::from(pid as i64)),
        ("tid", Json::from(endpoint.tid() as i64)),
        (
            "args",
            Json::obj(vec![("name", endpoint.name().into())]),
        ),
    ])
}

/// Build a trace-event JSON document from a merged event timeline.
pub fn chrome_trace(session_id: u64, events: &[Event]) -> Json {
    let pid = session_id as i64;
    let mut out: Vec<Json> = vec![
        thread_meta(session_id, Endpoint::Phone),
        thread_meta(session_id, Endpoint::Clone),
    ];
    // Open begins per (endpoint, trip, phase), matched LIFO.
    let mut open: Vec<(&Event, u8)> = Vec::new();
    for ev in events {
        match &ev.kind {
            EventKind::Begin(p) => open.push((ev, p.as_u8())),
            EventKind::End(p) => {
                let key = p.as_u8();
                if let Some(i) = open.iter().rposition(|&(b, ph)| {
                    ph == key && b.endpoint == ev.endpoint && b.trip == ev.trip
                }) {
                    let (b, _) = open.remove(i);
                    let mut args = base_args(b);
                    args.push((
                        "wall_dur_us",
                        Json::from(ev.wall_us.saturating_sub(b.wall_us) as i64),
                    ));
                    out.push(Json::obj(vec![
                        ("ph", "X".into()),
                        ("name", p.name().into()),
                        ("cat", if p.is_clone_side() { "clone" } else { "phone" }.into()),
                        ("pid", Json::from(pid)),
                        ("tid", Json::from(ev.endpoint.tid() as i64)),
                        ("ts", Json::from(b.virt_us)),
                        ("dur", Json::from((ev.virt_us - b.virt_us).max(0.0))),
                        ("args", Json::obj(args)),
                    ]));
                }
            }
            EventKind::Counter(c, v) => {
                out.push(Json::obj(vec![
                    ("ph", "C".into()),
                    ("name", c.name().into()),
                    ("pid", Json::from(pid)),
                    ("tid", Json::from(ev.endpoint.tid() as i64)),
                    ("ts", Json::from(ev.virt_us)),
                    ("args", Json::obj(vec![(c.name(), Json::from(*v))])),
                ]));
            }
            EventKind::Instant(m) => {
                out.push(Json::obj(vec![
                    ("ph", "i".into()),
                    ("s", "t".into()),
                    ("name", m.name().into()),
                    ("pid", Json::from(pid)),
                    ("tid", Json::from(ev.endpoint.tid() as i64)),
                    ("ts", Json::from(ev.virt_us)),
                    ("args", Json::obj(base_args(ev))),
                ]));
            }
            EventKind::Decision(d) => {
                out.push(Json::obj(vec![
                    ("ph", "i".into()),
                    ("s", "t".into()),
                    (
                        "name",
                        if d.mispredicted {
                            "decide:mispredicted"
                        } else {
                            "decide"
                        }
                        .into(),
                    ),
                    ("pid", Json::from(pid)),
                    ("tid", Json::from(ev.endpoint.tid() as i64)),
                    ("ts", Json::from(ev.virt_us)),
                    (
                        "args",
                        Json::obj(vec![
                            ("trip", Json::from(ev.trip as i64)),
                            ("offloaded", Json::from(d.offloaded)),
                            ("predicted_local_ms", Json::from(d.predicted_local_ms)),
                            ("predicted_offload_ms", Json::from(d.predicted_offload_ms)),
                            (
                                "predicted_fwd_bytes",
                                Json::from(d.predicted_fwd_bytes as i64),
                            ),
                            ("actual_ms", Json::from(d.actual_ms)),
                            ("mispredicted", Json::from(d.mispredicted)),
                        ]),
                    ),
                ]));
            }
        }
    }
    Json::obj(vec![
        ("displayTimeUnit", "ms".into()),
        ("traceEvents", Json::Arr(out)),
    ])
}

/// Emit the document as a JSON string.
pub fn chrome_trace_string(session_id: u64, events: &[Event]) -> String {
    emit(&chrome_trace(session_id, events))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Counter, Mark, Phase, Tracer};
    use crate::util::json::parse;

    #[test]
    fn export_is_valid_and_has_both_lanes() {
        let mut t = Tracer::new(0x5E55, Endpoint::Phone, 128);
        t.span(0, Phase::Capture, 0.0, 150.0);
        t.span(0, Phase::Uplink, 150.0, 400.0);
        t.counter(0, Counter::BytesUp, 2048.0, 400.0);
        t.instant(0, Mark::Heartbeat, 500.0);
        let mut clone = Tracer::new(0x5E55, Endpoint::Clone, 128);
        clone.span(0, Phase::CloneExec, 400.0, 900.0);
        t.absorb_remote(clone.events_since(0));

        let text = chrome_trace_string(0x5E55, &t.events().cloned().collect::<Vec<_>>());
        let doc = parse(&text).expect("export must be valid JSON");
        assert_eq!(doc.get("displayTimeUnit").as_str(), Some("ms"));
        let evs = doc.get("traceEvents").as_arr().expect("traceEvents array");
        // 2 thread metas + 3 slices + 1 counter + 1 instant.
        assert_eq!(evs.len(), 7);
        let tids: Vec<i64> = evs
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .map(|e| e.get("tid").as_i64().unwrap())
            .collect();
        assert!(tids.contains(&1) && tids.contains(&2), "both lanes present");
        let cap = evs
            .iter()
            .find(|e| e.get("name").as_str() == Some("capture"))
            .unwrap();
        assert_eq!(cap.get("dur").as_f64(), Some(150.0));
        assert_eq!(cap.get("pid").as_i64(), Some(0x5E55));
    }

    #[test]
    fn unmatched_begin_is_dropped_not_panicked() {
        let mut t = Tracer::new(1, Endpoint::Phone, 16);
        t.begin(0, Phase::Merge, 10.0);
        let text = chrome_trace_string(1, &t.events().cloned().collect::<Vec<_>>());
        let doc = parse(&text).unwrap();
        let slices = doc
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .count();
        assert_eq!(slices, 0);
    }
}
