//! Session flight recorder: phase-level distributed tracing.
//!
//! CloneCloud's evaluation explains every speedup as a phase breakdown —
//! suspend, capture, transfer, clone execution, merge, resume (§6,
//! Fig. 10). This module records that breakdown live: a bounded
//! ring-buffer of typed events ([`Event`]) stamped with both
//! virtual-clock µs (the simulated device/network time everything else
//! in the runtime is charged in) and wall µs (real host time, for
//! profiling the runtime itself).
//!
//! Design points, matching the codebase style:
//!
//! - **No globals.** An explicit [`Tracer`] handle is threaded through
//!   the exec driver, migration, CloneServer and farm workers. Code that
//!   doesn't trace passes [`Tracer::disabled()`].
//! - **Zero-cost disabled path.** Every record method early-returns on a
//!   single bool; a disabled tracer allocates nothing.
//! - **Bounded.** The ring holds `capacity` events; older events are
//!   dropped (counted in [`Tracer::dropped`]) rather than growing
//!   without bound — this is a flight recorder, not a log.
//! - **Observe-only.** Tracing must never change execution *results*.
//!   The wire context does add bytes to the (virtual-time-charged)
//!   link, but application state, migration counts and fallback
//!   behaviour are bit-identical with tracing on or off — enforced by
//!   test.
//!
//! Cross-endpoint causality lives in [`wire`]: a session-id + trip-seq +
//! parent-span context rides in front of the forward capsule (behind the
//! `CAP_TRACE_CTX` Hello capability bit), and the clone's own phase
//! events ship back piggybacked on the reverse capsule so one merged
//! timeline covers both endpoints. [`chrome`] exports that timeline as
//! Chrome trace-event JSON (load in Perfetto / `chrome://tracing`).

pub mod chrome;
pub mod wire;

pub use chrome::{chrome_trace, chrome_trace_string};
pub use wire::{
    prepend_ctx, prepend_events, split_ctx, split_events, TraceCtx, FLAG_WANT_CLONE_EVENTS,
    TRACE_CTX_LEN,
};

use crate::util::stats::LogHistogram;
use std::collections::VecDeque;
use std::time::Instant;

/// Which endpoint recorded an event. Becomes the `tid` lane in the
/// Chrome export, so phone and clone spans stack under one session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Endpoint {
    Phone,
    Clone,
}

impl Endpoint {
    pub fn name(self) -> &'static str {
        match self {
            Endpoint::Phone => "phone",
            Endpoint::Clone => "clone",
        }
    }
    pub fn tid(self) -> u32 {
        match self {
            Endpoint::Phone => 1,
            Endpoint::Clone => 2,
        }
    }
    pub fn as_u8(self) -> u8 {
        match self {
            Endpoint::Phone => 0,
            Endpoint::Clone => 1,
        }
    }
    pub fn from_u8(v: u8) -> Option<Endpoint> {
        match v {
            0 => Some(Endpoint::Phone),
            1 => Some(Endpoint::Clone),
            _ => None,
        }
    }
}

/// Offload phases, the span vocabulary of the recorder. Phone-side
/// phases mirror the paper's breakdown; `Clone*` phases are recorded at
/// the other endpoint and merged into the same timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Policy evaluation for one invocation (phone).
    Decide,
    /// Thread suspend at the migration point (phone).
    Suspend,
    /// Capture: heap/stack walk into the capsule (phone).
    Capture,
    /// Frame encode + optional compression (phone).
    Encode,
    /// Forward transfer on the virtual link (phone).
    Uplink,
    /// The phone-side wait while the clone works (phone).
    CloneTrip,
    /// Reverse transfer on the virtual link (phone).
    Downlink,
    /// Reintegration merge back into the phone process (phone).
    Merge,
    /// Local (non-offloaded) execution of the partition (phone).
    LocalExec,
    /// Frame decode + decompression at the clone.
    CloneDecode,
    /// Merge of the forward capsule into the clone process.
    CloneMerge,
    /// The offloaded partition running at the clone.
    CloneExec,
    /// Reverse capture at the clone.
    CloneCapture,
    /// Reverse frame encode at the clone.
    CloneEncode,
    /// Digest-heartbeat roundtrip on the virtual link (phone).
    Heartbeat,
    /// Tier-1 translation work at the clone (wall time spent promoting
    /// hot methods to direct-threaded form; charges no virtual time).
    Tier,
    /// One shard's trip window inside a scatter/gather offload (phone).
    /// Shard spans overlap in virtual time; the trip charges their max.
    ScatterShard,
    /// Gather merge: N disjoint reverse capsules applied against the
    /// single scatter baseline (phone).
    Gather,
}

/// All phases, for aggregation sweeps.
pub const PHASES: [Phase; 18] = [
    Phase::Decide,
    Phase::Suspend,
    Phase::Capture,
    Phase::Encode,
    Phase::Uplink,
    Phase::CloneTrip,
    Phase::Downlink,
    Phase::Merge,
    Phase::LocalExec,
    Phase::CloneDecode,
    Phase::CloneMerge,
    Phase::CloneExec,
    Phase::CloneCapture,
    Phase::CloneEncode,
    Phase::Heartbeat,
    Phase::Tier,
    Phase::ScatterShard,
    Phase::Gather,
];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Decide => "decide",
            Phase::Suspend => "suspend",
            Phase::Capture => "capture",
            Phase::Encode => "encode",
            Phase::Uplink => "uplink",
            Phase::CloneTrip => "clone_trip",
            Phase::Downlink => "downlink",
            Phase::Merge => "merge",
            Phase::LocalExec => "local_exec",
            Phase::CloneDecode => "clone_decode",
            Phase::CloneMerge => "clone_merge",
            Phase::CloneExec => "clone_exec",
            Phase::CloneCapture => "clone_capture",
            Phase::CloneEncode => "clone_encode",
            Phase::Heartbeat => "heartbeat",
            Phase::Tier => "tier",
            Phase::ScatterShard => "scatter_shard",
            Phase::Gather => "gather",
        }
    }
    pub fn as_u8(self) -> u8 {
        match self {
            Phase::Decide => 0,
            Phase::Suspend => 1,
            Phase::Capture => 2,
            Phase::Encode => 3,
            Phase::Uplink => 4,
            Phase::CloneTrip => 5,
            Phase::Downlink => 6,
            Phase::Merge => 7,
            Phase::LocalExec => 8,
            Phase::CloneDecode => 9,
            Phase::CloneMerge => 10,
            Phase::CloneExec => 11,
            Phase::CloneCapture => 12,
            Phase::CloneEncode => 13,
            Phase::Heartbeat => 14,
            Phase::Tier => 15,
            Phase::ScatterShard => 16,
            Phase::Gather => 17,
        }
    }
    pub fn from_u8(v: u8) -> Option<Phase> {
        PHASES.get(v as usize).copied()
    }
    /// Phases recorded at the clone endpoint.
    pub fn is_clone_side(self) -> bool {
        matches!(
            self,
            Phase::CloneDecode
                | Phase::CloneMerge
                | Phase::CloneExec
                | Phase::CloneCapture
                | Phase::CloneEncode
                | Phase::Tier
        )
    }
}

/// Named scalar counters attached to a trip.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Counter {
    BytesUp,
    BytesDown,
    ObjectsShipped,
    PagesDirty,
    Instrs,
    DictHitBytes,
}

pub const COUNTERS: [Counter; 6] = [
    Counter::BytesUp,
    Counter::BytesDown,
    Counter::ObjectsShipped,
    Counter::PagesDirty,
    Counter::Instrs,
    Counter::DictHitBytes,
];

impl Counter {
    pub fn name(self) -> &'static str {
        match self {
            Counter::BytesUp => "bytes_up",
            Counter::BytesDown => "bytes_down",
            Counter::ObjectsShipped => "objects_shipped",
            Counter::PagesDirty => "pages_dirty",
            Counter::Instrs => "instrs",
            Counter::DictHitBytes => "dict_hit_bytes",
        }
    }
    pub fn as_u8(self) -> u8 {
        match self {
            Counter::BytesUp => 0,
            Counter::BytesDown => 1,
            Counter::ObjectsShipped => 2,
            Counter::PagesDirty => 3,
            Counter::Instrs => 4,
            Counter::DictHitBytes => 5,
        }
    }
    pub fn from_u8(v: u8) -> Option<Counter> {
        COUNTERS.get(v as usize).copied()
    }
}

/// Point-in-time markers (no duration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Mark {
    /// Delta capsule rejected by the clone; full-recapture fallback.
    NeedFull,
    /// Session dictionary reset.
    DictReset,
    /// Heartbeat digest diverged.
    HeartbeatDivergent,
    /// Offload attempt degraded to local execution.
    Degrade,
    /// Idle heartbeat probe sent.
    Heartbeat,
    /// Mobile-side GC ran during capture.
    MobileGc,
    /// Scatter gather found overlapping dirty state; the trip degraded
    /// to a single-clone offload (never a corrupted merge).
    ScatterConflict,
    /// Marginal decision: local interpretation raced the offload; the
    /// instant records the commit of whichever leg finished first.
    Speculate,
}

pub const MARKS: [Mark; 8] = [
    Mark::NeedFull,
    Mark::DictReset,
    Mark::HeartbeatDivergent,
    Mark::Degrade,
    Mark::Heartbeat,
    Mark::MobileGc,
    Mark::ScatterConflict,
    Mark::Speculate,
];

impl Mark {
    pub fn name(self) -> &'static str {
        match self {
            Mark::NeedFull => "need_full",
            Mark::DictReset => "dict_reset",
            Mark::HeartbeatDivergent => "heartbeat_divergent",
            Mark::Degrade => "degrade",
            Mark::Heartbeat => "heartbeat",
            Mark::MobileGc => "mobile_gc",
            Mark::ScatterConflict => "scatter_conflict",
            Mark::Speculate => "speculate",
        }
    }
    pub fn as_u8(self) -> u8 {
        match self {
            Mark::NeedFull => 0,
            Mark::DictReset => 1,
            Mark::HeartbeatDivergent => 2,
            Mark::Degrade => 3,
            Mark::Heartbeat => 4,
            Mark::MobileGc => 5,
            Mark::ScatterConflict => 6,
            Mark::Speculate => 7,
        }
    }
    pub fn from_u8(v: u8) -> Option<Mark> {
        MARKS.get(v as usize).copied()
    }
}

/// A policy decision record: the predicted per-term costs next to what
/// actually happened, so every misprediction is explainable post-hoc.
/// Decision events are phone-only; they never cross the wire envelope
/// in practice (the clone has no policy engine) but encode fine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecisionEvent {
    pub offloaded: bool,
    /// Predicted local cost (ms) at decision time.
    pub predicted_local_ms: f64,
    /// Predicted offload cost (ms) at decision time.
    pub predicted_offload_ms: f64,
    /// Predicted forward payload (bytes) at decision time.
    pub predicted_fwd_bytes: u64,
    /// Measured cost (ms) of the path actually taken.
    pub actual_ms: f64,
    /// Whether post-hoc scoring judged the choice wrong.
    pub mispredicted: bool,
}

/// Event payloads.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    Begin(Phase),
    End(Phase),
    Counter(Counter, f64),
    Instant(Mark),
    Decision(DecisionEvent),
}

/// One recorded event. `virt_us` is virtual-clock time (comparable
/// across endpoints — the clone runs on the phone's shipped clock);
/// `wall_us` is host wall time since the recording tracer's epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub endpoint: Endpoint,
    pub trip: u32,
    pub virt_us: f64,
    pub wall_us: u64,
    pub kind: EventKind,
}

/// Bounded flight recorder. Construct with [`Tracer::new`] to record or
/// [`Tracer::disabled`] for the zero-cost pass-through.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    session_id: u64,
    endpoint: Endpoint,
    ship_clone_events: bool,
    capacity: usize,
    ring: VecDeque<Event>,
    seq: u64,
    dropped: u64,
    epoch: Instant,
}

impl Tracer {
    /// An enabled recorder with the given ring capacity (min 16).
    pub fn new(session_id: u64, endpoint: Endpoint, capacity: usize) -> Tracer {
        let capacity = capacity.max(16);
        Tracer {
            enabled: true,
            session_id,
            endpoint,
            ship_clone_events: true,
            capacity,
            ring: VecDeque::with_capacity(capacity),
            seq: 0,
            dropped: 0,
            epoch: Instant::now(),
        }
    }

    /// The zero-cost path: every record method returns immediately and
    /// nothing is ever allocated.
    pub fn disabled() -> Tracer {
        Tracer {
            enabled: false,
            session_id: 0,
            endpoint: Endpoint::Phone,
            ship_clone_events: false,
            capacity: 0,
            ring: VecDeque::new(),
            seq: 0,
            dropped: 0,
            epoch: Instant::now(),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }
    pub fn session_id(&self) -> u64 {
        self.session_id
    }
    pub fn endpoint(&self) -> Endpoint {
        self.endpoint
    }
    /// Whether the phone side asks the clone to ship its events back.
    pub fn ship_clone_events(&self) -> bool {
        self.enabled && self.ship_clone_events
    }
    pub fn set_ship_clone_events(&mut self, ship: bool) {
        self.ship_clone_events = ship;
    }

    /// Events recorded so far (oldest first), ring-bounded.
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.ring.iter()
    }
    pub fn len(&self) -> usize {
        self.ring.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }
    /// Events evicted by the ring bound.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Wall µs since this tracer's construction.
    fn wall_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn push(&mut self, trip: u32, virt_us: f64, kind: EventKind) {
        if !self.enabled {
            return;
        }
        let wall = self.wall_us();
        self.push_at(trip, virt_us, wall, kind);
    }

    fn push_at(&mut self, trip: u32, virt_us: f64, wall_us: u64, kind: EventKind) {
        if !self.enabled {
            return;
        }
        let ev = Event {
            seq: self.seq,
            endpoint: self.endpoint,
            trip,
            virt_us,
            wall_us,
            kind,
        };
        self.seq += 1;
        if self.ring.len() == self.capacity {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(ev);
    }

    pub fn begin(&mut self, trip: u32, phase: Phase, virt_us: f64) {
        self.push(trip, virt_us, EventKind::Begin(phase));
    }

    pub fn end(&mut self, trip: u32, phase: Phase, virt_us: f64) {
        self.push(trip, virt_us, EventKind::End(phase));
    }

    /// Record a whole span from its virtual endpoints — used when the
    /// duration was measured elsewhere (e.g. `MigrationPhases`) and is
    /// being reconstructed onto the timeline after the fact.
    pub fn span(&mut self, trip: u32, phase: Phase, start_virt_us: f64, end_virt_us: f64) {
        if !self.enabled {
            return;
        }
        self.begin(trip, phase, start_virt_us);
        self.end(trip, phase, end_virt_us.max(start_virt_us));
    }

    /// Record a span that sits at a single point of virtual time but
    /// took `wall_dur_us` of measured wall time — decode/encode work
    /// that is not charged to the virtual clock.
    pub fn span_wall(&mut self, trip: u32, phase: Phase, virt_us: f64, wall_dur_us: u64) {
        if !self.enabled {
            return;
        }
        let now = self.wall_us();
        self.push_at(
            trip,
            virt_us,
            now.saturating_sub(wall_dur_us),
            EventKind::Begin(phase),
        );
        self.push_at(trip, virt_us, now, EventKind::End(phase));
    }

    pub fn counter(&mut self, trip: u32, c: Counter, value: f64, virt_us: f64) {
        self.push(trip, virt_us, EventKind::Counter(c, value));
    }

    pub fn instant(&mut self, trip: u32, m: Mark, virt_us: f64) {
        self.push(trip, virt_us, EventKind::Instant(m));
    }

    pub fn decision(&mut self, trip: u32, d: DecisionEvent, virt_us: f64) {
        self.push(trip, virt_us, EventKind::Decision(d));
    }

    /// A watermark for [`Tracer::events_since`] — take it before a unit
    /// of work to collect exactly that work's events afterwards.
    pub fn mark(&self) -> u64 {
        self.seq
    }

    /// Events with `seq >= mark` (clones; the ring keeps its copy).
    pub fn events_since(&self, mark: u64) -> Vec<Event> {
        self.ring
            .iter()
            .filter(|e| e.seq >= mark)
            .cloned()
            .collect()
    }

    /// Merge events recorded at the other endpoint (decoded off the
    /// reverse capsule) into this timeline. Remote virtual stamps are
    /// kept verbatim — the clone ran on the phone's shipped virtual
    /// clock, so they are directly comparable; remote wall stamps are
    /// kept too but belong to the remote host's epoch. Each absorbed
    /// event gets a fresh local `seq` and counts against the ring bound.
    pub fn absorb_remote(&mut self, events: Vec<Event>) {
        if !self.enabled {
            return;
        }
        for mut ev in events {
            ev.seq = self.seq;
            self.seq += 1;
            if self.ring.len() == self.capacity {
                self.ring.pop_front();
                self.dropped += 1;
            }
            self.ring.push_back(ev);
        }
    }

    /// Aggregate the ring into per-phase percentile summaries.
    pub fn report(&self) -> TraceReport {
        TraceReport::from_events(self.session_id, self.dropped, self.ring.iter())
    }
}

/// Per-(endpoint, phase) streaming summary.
#[derive(Debug, Clone)]
pub struct PhaseSummary {
    pub endpoint: Endpoint,
    pub phase: Phase,
    pub hist: LogHistogram,
}

/// Aggregated view of a trace: per-phase virtual-duration histograms
/// (ms), plus counter totals and instant counts. This is the shape
/// `MetricsSnapshot::absorb_trace` consumes.
#[derive(Debug, Clone, Default)]
pub struct TraceReport {
    pub session_id: u64,
    pub events: u64,
    pub dropped: u64,
    pub phases: Vec<PhaseSummary>,
    /// (counter, total) in event order of first appearance.
    pub counters: Vec<(Counter, f64)>,
    /// (mark, occurrences).
    pub instants: Vec<(Mark, u64)>,
    pub decisions: u64,
    pub mispredictions: u64,
}

impl TraceReport {
    pub fn from_events<'a, I>(session_id: u64, dropped: u64, events: I) -> TraceReport
    where
        I: Iterator<Item = &'a Event>,
    {
        let mut rep = TraceReport {
            session_id,
            dropped,
            ..TraceReport::default()
        };
        // Open-span stack per (endpoint, trip, phase). Spans of one
        // phase never nest in practice; a Vec handles it if they do.
        let mut open: Vec<(Endpoint, u32, Phase, f64)> = Vec::new();
        for ev in events {
            rep.events += 1;
            match &ev.kind {
                EventKind::Begin(p) => {
                    open.push((ev.endpoint, ev.trip, *p, ev.virt_us));
                }
                EventKind::End(p) => {
                    if let Some(i) = open
                        .iter()
                        .rposition(|&(e, t, ph, _)| e == ev.endpoint && t == ev.trip && ph == *p)
                    {
                        let (_, _, _, start) = open.remove(i);
                        let dur_ms = (ev.virt_us - start).max(0.0) / 1000.0;
                        rep.phase_mut(ev.endpoint, *p).hist.record(dur_ms);
                    }
                }
                EventKind::Counter(c, v) => {
                    match rep.counters.iter_mut().find(|(k, _)| k == c) {
                        Some((_, total)) => *total += v,
                        None => rep.counters.push((*c, *v)),
                    }
                }
                EventKind::Instant(m) => match rep.instants.iter_mut().find(|(k, _)| k == m) {
                    Some((_, n)) => *n += 1,
                    None => rep.instants.push((*m, 1)),
                },
                EventKind::Decision(d) => {
                    rep.decisions += 1;
                    if d.mispredicted {
                        rep.mispredictions += 1;
                    }
                }
            }
        }
        rep
    }

    fn phase_mut(&mut self, endpoint: Endpoint, phase: Phase) -> &mut PhaseSummary {
        if let Some(i) = self
            .phases
            .iter()
            .position(|s| s.endpoint == endpoint && s.phase == phase)
        {
            return &mut self.phases[i];
        }
        self.phases.push(PhaseSummary {
            endpoint,
            phase,
            hist: LogHistogram::new(),
        });
        self.phases.last_mut().unwrap()
    }

    pub fn phase(&self, endpoint: Endpoint, phase: Phase) -> Option<&PhaseSummary> {
        self.phases
            .iter()
            .find(|s| s.endpoint == endpoint && s.phase == phase)
    }
}

/// Fraction of trip virtual time covered by phone-side phase spans:
/// `sum(span durations) / sum(trip window lengths)` over all trips that
/// have at least one phone-side span. Phone phases are sequential and
/// non-overlapping (the clone's work happens inside `CloneTrip`), so a
/// well-instrumented driver approaches 1.0; the acceptance bar is 0.95.
pub fn phone_coverage(events: &[Event]) -> f64 {
    // Paired (start, end) per completed phone-side span, keyed by trip.
    let mut open: Vec<(u32, Phase, f64)> = Vec::new();
    // trip -> (window_lo, window_hi, covered)
    let mut trips: Vec<(u32, f64, f64, f64)> = Vec::new();
    for ev in events {
        if ev.endpoint != Endpoint::Phone {
            continue;
        }
        match &ev.kind {
            EventKind::Begin(p) => open.push((ev.trip, *p, ev.virt_us)),
            EventKind::End(p) => {
                if let Some(i) = open
                    .iter()
                    .rposition(|&(t, ph, _)| t == ev.trip && ph == *p)
                {
                    let (trip, phase, start) = open.remove(i);
                    // Decide overlaps nothing by construction but is
                    // instantaneous in virtual time; include it anyway.
                    let _ = phase;
                    let dur = (ev.virt_us - start).max(0.0);
                    match trips.iter_mut().find(|(t, ..)| *t == trip) {
                        Some((_, lo, hi, cov)) => {
                            *lo = lo.min(start);
                            *hi = hi.max(ev.virt_us);
                            *cov += dur;
                        }
                        None => trips.push((trip, start, ev.virt_us, dur)),
                    }
                }
            }
            _ => {}
        }
    }
    let window: f64 = trips.iter().map(|(_, lo, hi, _)| hi - lo).sum();
    let covered: f64 = trips.iter().map(|(_, _, _, c)| c).sum();
    if window <= 0.0 {
        return if covered >= 0.0 && !trips.is_empty() {
            1.0
        } else {
            0.0
        };
    }
    (covered / window).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::disabled();
        t.begin(0, Phase::Capture, 0.0);
        t.end(0, Phase::Capture, 10.0);
        t.counter(0, Counter::BytesUp, 100.0, 10.0);
        t.instant(0, Mark::NeedFull, 10.0);
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
        assert!(!t.ship_clone_events());
    }

    #[test]
    fn ring_bounds_and_counts_drops() {
        let mut t = Tracer::new(7, Endpoint::Phone, 16);
        for i in 0..40 {
            t.instant(i, Mark::Heartbeat, i as f64);
        }
        assert_eq!(t.len(), 16);
        assert_eq!(t.dropped(), 24);
        // Oldest surviving event is seq 24.
        assert_eq!(t.events().next().unwrap().seq, 24);
    }

    #[test]
    fn report_pairs_spans_and_aggregates() {
        let mut t = Tracer::new(1, Endpoint::Phone, 64);
        for trip in 0..10u32 {
            let base = trip as f64 * 1000.0;
            t.span(trip, Phase::Capture, base, base + 200.0);
            t.span(trip, Phase::Uplink, base + 200.0, base + 700.0);
            t.counter(trip, Counter::BytesUp, 64.0, base + 700.0);
        }
        t.instant(0, Mark::NeedFull, 5.0);
        let rep = t.report();
        let cap = rep.phase(Endpoint::Phone, Phase::Capture).unwrap();
        assert_eq!(cap.hist.count(), 10);
        assert!((cap.hist.p50() - 0.2).abs() / 0.2 < 0.1, "p50 ~0.2ms");
        let up = rep.phase(Endpoint::Phone, Phase::Uplink).unwrap();
        assert!((up.hist.mean() - 0.5).abs() / 0.5 < 0.1);
        assert_eq!(rep.counters, vec![(Counter::BytesUp, 640.0)]);
        assert_eq!(rep.instants, vec![(Mark::NeedFull, 1)]);
    }

    #[test]
    fn events_since_mark_isolates_new_work() {
        let mut t = Tracer::new(1, Endpoint::Clone, 64);
        t.span(0, Phase::CloneExec, 0.0, 10.0);
        let m = t.mark();
        t.span(1, Phase::CloneExec, 20.0, 30.0);
        let evs = t.events_since(m);
        assert_eq!(evs.len(), 2);
        assert!(evs.iter().all(|e| e.trip == 1));
    }

    #[test]
    fn absorb_remote_merges_clone_timeline() {
        let mut phone = Tracer::new(9, Endpoint::Phone, 64);
        phone.span(0, Phase::Uplink, 0.0, 100.0);
        let mut clone = Tracer::new(9, Endpoint::Clone, 64);
        clone.span(0, Phase::CloneExec, 100.0, 400.0);
        phone.absorb_remote(clone.events_since(0));
        let rep = phone.report();
        assert!(rep.phase(Endpoint::Phone, Phase::Uplink).is_some());
        let ce = rep.phase(Endpoint::Clone, Phase::CloneExec).unwrap();
        assert!((ce.hist.mean() - 0.3).abs() < 0.05);
        // Fresh local seqs, monotone.
        let seqs: Vec<u64> = phone.events().map(|e| e.seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn coverage_full_and_partial() {
        let mut t = Tracer::new(1, Endpoint::Phone, 64);
        // Trip 0: spans tile [0, 100] fully.
        t.span(0, Phase::Capture, 0.0, 40.0);
        t.span(0, Phase::Uplink, 40.0, 100.0);
        let evs: Vec<Event> = t.events().cloned().collect();
        assert!((phone_coverage(&evs) - 1.0).abs() < 1e-9);
        // Trip 1: a 50% hole.
        t.span(1, Phase::Capture, 200.0, 250.0);
        t.span(1, Phase::Merge, 300.0, 300.0);
        let evs: Vec<Event> = t.events().cloned().collect();
        let cov = phone_coverage(&evs);
        assert!(cov > 0.7 && cov < 0.8, "got {cov}");
    }

    #[test]
    fn decision_misprediction_tallies() {
        let mut t = Tracer::new(1, Endpoint::Phone, 64);
        let d = DecisionEvent {
            offloaded: true,
            predicted_local_ms: 10.0,
            predicted_offload_ms: 4.0,
            predicted_fwd_bytes: 512,
            actual_ms: 12.0,
            mispredicted: true,
        };
        t.decision(0, d, 0.0);
        t.decision(
            1,
            DecisionEvent {
                mispredicted: false,
                ..d
            },
            1.0,
        );
        let rep = t.report();
        assert_eq!(rep.decisions, 2);
        assert_eq!(rep.mispredictions, 1);
    }
}
