//! Trace context and event piggybacking on the migration wire.
//!
//! Both envelopes sit *inside* the sealed frame, in front of the capsule
//! bytes, and are self-describing by magic — a receiver that was not
//! told to expect one still parses the payload correctly, and a payload
//! without one passes through untouched (`split_*` returns the input
//! unchanged). Presence is negotiated by the `CAP_TRACE_CTX` Hello bit
//! (proto >= 4); per the PR 3 invariant the bit is ignored by older
//! peers, and these envelopes are never attached unless both ends
//! advertised it.
//!
//! Forward direction (phone → clone), fixed [`TRACE_CTX_LEN`] bytes:
//!
//! ```text
//! magic "CCTC" (u32) | ver u8 | flags u8 | session_id u64 | trip u32 | parent_span u32
//! ```
//!
//! Reverse direction (clone → phone): magic "CCTR" (u32) | ver u8 |
//! length-prefixed event blob, then the reverse capsule. Event records
//! are fixed-layout per kind; garbage input yields `Err`, never a panic
//! (property-tested).

use super::{Counter, DecisionEvent, Endpoint, Event, EventKind, Mark, Phase};
use crate::error::{CloneCloudError, Result};
use crate::util::bytes::{WireReader, WireWriter};

/// "CCTC" — forward trace context.
pub const TRACE_CTX_MAGIC: u32 = 0x4343_5443;
/// "CCTR" — reverse trace event blob.
pub const TRACE_EVT_MAGIC: u32 = 0x4343_5452;
pub const TRACE_WIRE_VERSION: u8 = 1;

/// Forward flag: the phone wants the clone's phase events shipped back.
pub const FLAG_WANT_CLONE_EVENTS: u8 = 1;

/// Encoded size of a forward context: magic + ver + flags + session_id +
/// trip + parent_span.
pub const TRACE_CTX_LEN: usize = 4 + 1 + 1 + 8 + 4 + 4;

/// Minimum encoded size of one event record (an Instant):
/// kind + endpoint + code + trip + virt + wall.
const EVENT_MIN_LEN: usize = 1 + 1 + 1 + 4 + 8 + 8;

/// Cross-endpoint causality context: identifies which session, trip and
/// parent span a forward capsule belongs to, so the clone's events can
/// be merged into the right place on the phone's timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub session_id: u64,
    pub trip: u32,
    /// Sequence number of the phone-side span this work nests under
    /// (the `CloneTrip` begin event).
    pub parent_span: u32,
    pub flags: u8,
}

impl TraceCtx {
    pub fn wants_clone_events(&self) -> bool {
        self.flags & FLAG_WANT_CLONE_EVENTS != 0
    }
}

fn encode_ctx(ctx: &TraceCtx, w: &mut WireWriter) {
    w.put_u32(TRACE_CTX_MAGIC);
    w.put_u8(TRACE_WIRE_VERSION);
    w.put_u8(ctx.flags);
    w.put_u64(ctx.session_id);
    w.put_u32(ctx.trip);
    w.put_u32(ctx.parent_span);
}

/// Attach a forward context in front of capsule bytes.
pub fn prepend_ctx(ctx: &TraceCtx, capsule: &[u8]) -> Vec<u8> {
    let mut w = WireWriter::with_capacity(TRACE_CTX_LEN + capsule.len());
    encode_ctx(ctx, &mut w);
    let mut out = w.into_vec();
    out.extend_from_slice(capsule);
    out
}

/// Split a forward payload into its optional context and the capsule
/// bytes. A payload that does not start with the magic is returned
/// whole with no context; a payload that *does* but is truncated or has
/// an unknown version is an error (the magic is 4 bytes of a sealed,
/// CRC-checked frame — a chance collision with capsule data cannot
/// happen because capsules start with their own magic).
pub fn split_ctx(buf: &[u8]) -> Result<(Option<TraceCtx>, &[u8])> {
    if buf.len() < 4 {
        return Ok((None, buf));
    }
    let magic = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if magic != TRACE_CTX_MAGIC {
        return Ok((None, buf));
    }
    let mut r = WireReader::new(&buf[4..]);
    let ver = r.get_u8()?;
    if ver != TRACE_WIRE_VERSION {
        return Err(CloneCloudError::Wire(format!(
            "trace ctx version {ver} unsupported"
        )));
    }
    let flags = r.get_u8()?;
    let session_id = r.get_u64()?;
    let trip = r.get_u32()?;
    let parent_span = r.get_u32()?;
    Ok((
        Some(TraceCtx {
            session_id,
            trip,
            parent_span,
            flags,
        }),
        &buf[TRACE_CTX_LEN..],
    ))
}

fn encode_event(ev: &Event, w: &mut WireWriter) {
    let (kind, code) = match &ev.kind {
        EventKind::Begin(p) => (0u8, p.as_u8()),
        EventKind::End(p) => (1, p.as_u8()),
        EventKind::Counter(c, _) => (2, c.as_u8()),
        EventKind::Instant(m) => (3, m.as_u8()),
        EventKind::Decision(d) => (4, d.offloaded as u8),
    };
    w.put_u8(kind);
    w.put_u8(ev.endpoint.as_u8());
    w.put_u8(code);
    w.put_u32(ev.trip);
    w.put_f64(ev.virt_us);
    w.put_u64(ev.wall_us);
    match &ev.kind {
        EventKind::Counter(_, v) => w.put_f64(*v),
        EventKind::Decision(d) => {
            w.put_u8(d.mispredicted as u8);
            w.put_f64(d.predicted_local_ms);
            w.put_f64(d.predicted_offload_ms);
            w.put_u64(d.predicted_fwd_bytes);
            w.put_f64(d.actual_ms);
        }
        _ => {}
    }
}

fn decode_event(r: &mut WireReader) -> Result<Event> {
    let kind = r.get_u8()?;
    let endpoint = Endpoint::from_u8(r.get_u8()?)
        .ok_or_else(|| CloneCloudError::Wire("bad trace endpoint".into()))?;
    let code = r.get_u8()?;
    let trip = r.get_u32()?;
    let virt_us = r.get_f64()?;
    let wall_us = r.get_u64()?;
    let bad = |what: &str| CloneCloudError::Wire(format!("bad trace {what} code {code}"));
    let kind = match kind {
        0 => EventKind::Begin(Phase::from_u8(code).ok_or_else(|| bad("phase"))?),
        1 => EventKind::End(Phase::from_u8(code).ok_or_else(|| bad("phase"))?),
        2 => EventKind::Counter(
            Counter::from_u8(code).ok_or_else(|| bad("counter"))?,
            r.get_f64()?,
        ),
        3 => EventKind::Instant(Mark::from_u8(code).ok_or_else(|| bad("mark"))?),
        4 => {
            if code > 1 {
                return Err(bad("decision"));
            }
            let mispredicted = r.get_u8()? != 0;
            EventKind::Decision(DecisionEvent {
                offloaded: code != 0,
                mispredicted,
                predicted_local_ms: r.get_f64()?,
                predicted_offload_ms: r.get_f64()?,
                predicted_fwd_bytes: r.get_u64()?,
                actual_ms: r.get_f64()?,
            })
        }
        k => {
            return Err(CloneCloudError::Wire(format!(
                "unknown trace event kind {k}"
            )))
        }
    };
    Ok(Event {
        seq: 0, // reassigned by the absorbing tracer
        endpoint,
        trip,
        virt_us,
        wall_us,
        kind,
    })
}

/// Encode events into a standalone blob (no magic; used inside the
/// reverse envelope and directly testable).
pub fn encode_events(events: &[Event]) -> Result<Vec<u8>> {
    let mut w = WireWriter::with_capacity(8 + events.len() * 32);
    w.put_count(events.len())?;
    for ev in events {
        encode_event(ev, &mut w);
    }
    Ok(w.into_vec())
}

/// Decode an event blob produced by [`encode_events`].
pub fn decode_events(buf: &[u8]) -> Result<Vec<Event>> {
    let mut r = WireReader::new(buf);
    let n = r.get_u32()? as usize;
    let n = r.checked_count(n, EVENT_MIN_LEN)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(decode_event(&mut r)?);
    }
    if !r.is_done() {
        return Err(CloneCloudError::Wire(format!(
            "{} trailing bytes after trace events",
            r.remaining()
        )));
    }
    Ok(out)
}

/// Attach a reverse event blob in front of the reverse capsule bytes.
pub fn prepend_events(events: &[Event], capsule: &[u8]) -> Result<Vec<u8>> {
    let blob = encode_events(events)?;
    let mut w = WireWriter::with_capacity(4 + 1 + 4 + blob.len() + capsule.len());
    w.put_u32(TRACE_EVT_MAGIC);
    w.put_u8(TRACE_WIRE_VERSION);
    w.put_bytes(&blob);
    let mut out = w.into_vec();
    out.extend_from_slice(capsule);
    Ok(out)
}

/// Split a reverse payload into piggybacked events (possibly none) and
/// the capsule bytes. Same self-describing contract as [`split_ctx`].
pub fn split_events(buf: &[u8]) -> Result<(Vec<Event>, &[u8])> {
    if buf.len() < 4 {
        return Ok((Vec::new(), buf));
    }
    let magic = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if magic != TRACE_EVT_MAGIC {
        return Ok((Vec::new(), buf));
    }
    let mut r = WireReader::new(&buf[4..]);
    let ver = r.get_u8()?;
    if ver != TRACE_WIRE_VERSION {
        return Err(CloneCloudError::Wire(format!(
            "trace event version {ver} unsupported"
        )));
    }
    let blob = r.get_bytes()?;
    let events = decode_events(&blob)?;
    let consumed = buf.len() - r.remaining();
    Ok((events, &buf[consumed..]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, ensure_eq, forall, PropConfig};
    use crate::util::rng::Rng;

    fn arb_event(rng: &mut Rng) -> Event {
        let endpoint = if rng.next_u64() % 2 == 0 {
            Endpoint::Phone
        } else {
            Endpoint::Clone
        };
        let trip = (rng.next_u64() % 1000) as u32;
        let virt_us = (rng.next_u64() % 1_000_000) as f64 / 3.0;
        let wall_us = rng.next_u64() % 1_000_000;
        let kind = match rng.next_u64() % 5 {
            0 => EventKind::Begin(Phase::from_u8((rng.next_u64() % 18) as u8).unwrap()),
            1 => EventKind::End(Phase::from_u8((rng.next_u64() % 18) as u8).unwrap()),
            2 => EventKind::Counter(
                Counter::from_u8((rng.next_u64() % 6) as u8).unwrap(),
                (rng.next_u64() % 1_000_000) as f64,
            ),
            3 => EventKind::Instant(Mark::from_u8((rng.next_u64() % 8) as u8).unwrap()),
            _ => EventKind::Decision(DecisionEvent {
                offloaded: rng.next_u64() % 2 == 0,
                mispredicted: rng.next_u64() % 2 == 0,
                predicted_local_ms: (rng.next_u64() % 10_000) as f64 / 7.0,
                predicted_offload_ms: (rng.next_u64() % 10_000) as f64 / 11.0,
                predicted_fwd_bytes: rng.next_u64() % (1 << 20),
                actual_ms: (rng.next_u64() % 10_000) as f64 / 13.0,
            }),
        };
        Event {
            seq: 0,
            endpoint,
            trip,
            virt_us,
            wall_us,
            kind,
        }
    }

    #[test]
    fn ctx_roundtrip_and_passthrough() {
        let ctx = TraceCtx {
            session_id: 0xDEAD_BEEF_0042,
            trip: 17,
            parent_span: 99,
            flags: FLAG_WANT_CLONE_EVENTS,
        };
        let capsule = b"CCAP-not-really-a-capsule".to_vec();
        let buf = prepend_ctx(&ctx, &capsule);
        assert_eq!(buf.len(), TRACE_CTX_LEN + capsule.len());
        let (got, rest) = split_ctx(&buf).unwrap();
        assert_eq!(got, Some(ctx));
        assert!(got.unwrap().wants_clone_events());
        assert_eq!(rest, &capsule[..]);
        // No envelope → untouched.
        let (none, rest) = split_ctx(&capsule).unwrap();
        assert!(none.is_none());
        assert_eq!(rest, &capsule[..]);
        // Short buffers are fine too.
        assert!(split_ctx(&[1, 2]).unwrap().0.is_none());
    }

    #[test]
    fn events_roundtrip_with_capsule() {
        let mut rng = Rng::new(42);
        let events: Vec<Event> = (0..20).map(|_| arb_event(&mut rng)).collect();
        let capsule = vec![0xAB; 300];
        let buf = prepend_events(&events, &capsule).unwrap();
        let (got, rest) = split_events(&buf).unwrap();
        assert_eq!(got, events);
        assert_eq!(rest, &capsule[..]);
        // Empty event list still frames correctly.
        let buf = prepend_events(&[], &capsule).unwrap();
        let (got, rest) = split_events(&buf).unwrap();
        assert!(got.is_empty());
        assert_eq!(rest, &capsule[..]);
    }

    #[test]
    fn prop_event_blob_roundtrip() {
        forall(
            PropConfig::default(),
            |rng| {
                let n = (rng.next_u64() % 40) as usize;
                (0..n).map(|_| arb_event(rng)).collect::<Vec<Event>>()
            },
            |events| {
                let blob = encode_events(events).map_err(|e| format!("encode: {e}"))?;
                let back = decode_events(&blob)
                    .map_err(|e| format!("decode failed on own encoding: {e}"))?;
                ensure_eq(back.len(), events.len(), "event count")?;
                ensure(&back == events, "events mutated by roundtrip")
            },
        );
    }

    #[test]
    fn prop_ctx_roundtrip_any_payload() {
        forall(
            PropConfig::default(),
            |rng| {
                let ctx = TraceCtx {
                    session_id: rng.next_u64(),
                    trip: (rng.next_u64() & 0xFFFF_FFFF) as u32,
                    parent_span: (rng.next_u64() & 0xFFFF_FFFF) as u32,
                    flags: (rng.next_u64() % 2) as u8 * FLAG_WANT_CLONE_EVENTS,
                };
                let n = (rng.next_u64() % 300) as usize;
                let capsule: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
                (ctx, capsule)
            },
            |(ctx, capsule)| {
                let buf = prepend_ctx(ctx, capsule);
                let (got, rest) =
                    split_ctx(&buf).map_err(|e| format!("split on own encoding: {e}"))?;
                ensure_eq(got, Some(*ctx), "ctx")?;
                ensure(rest == &capsule[..], "capsule bytes mutated")
            },
        );
    }

    #[test]
    fn prop_strict_prefix_never_decodes() {
        forall(
            PropConfig::default(),
            |rng| {
                let n = 1 + (rng.next_u64() % 10) as usize;
                let events: Vec<Event> = (0..n).map(|_| arb_event(rng)).collect();
                let blob = encode_events(&events).unwrap();
                let cut = 1 + (rng.next_u64() as usize) % (blob.len() - 1);
                (blob, cut)
            },
            |(blob, cut)| {
                ensure(
                    decode_events(&blob[..*cut]).is_err(),
                    "strict prefix decoded successfully",
                )
            },
        );
    }

    #[test]
    fn prop_garbage_never_panics() {
        forall(
            PropConfig {
                cases: 300,
                ..PropConfig::default()
            },
            |rng| {
                let n = (rng.next_u64() % 200) as usize;
                let mut buf: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
                // Half the time, graft a real magic on front so the
                // parsers go past the early-out.
                match rng.next_u64() % 4 {
                    0 if buf.len() >= 4 => {
                        buf[..4].copy_from_slice(&TRACE_CTX_MAGIC.to_be_bytes())
                    }
                    1 if buf.len() >= 4 => {
                        buf[..4].copy_from_slice(&TRACE_EVT_MAGIC.to_be_bytes())
                    }
                    _ => {}
                }
                buf
            },
            |buf| {
                // Any outcome but a panic is acceptable.
                let _ = split_ctx(buf);
                let _ = split_events(buf);
                let _ = decode_events(buf);
                Ok(())
            },
        );
    }

    #[test]
    fn truncated_envelope_after_magic_is_error() {
        let mut buf = TRACE_CTX_MAGIC.to_be_bytes().to_vec();
        buf.push(TRACE_WIRE_VERSION);
        assert!(split_ctx(&buf).is_err(), "truncated ctx must not pass");
        let mut buf = TRACE_EVT_MAGIC.to_be_bytes().to_vec();
        buf.push(TRACE_WIRE_VERSION);
        buf.extend_from_slice(&[0, 0, 0, 50]); // blob length beyond buffer
        assert!(split_events(&buf).is_err());
    }

    #[test]
    fn unknown_version_is_error_not_passthrough() {
        let ctx = TraceCtx {
            session_id: 1,
            trip: 0,
            parent_span: 0,
            flags: 0,
        };
        let mut buf = prepend_ctx(&ctx, b"x");
        buf[4] = 99; // version byte
        assert!(split_ctx(&buf).is_err());
    }
}
