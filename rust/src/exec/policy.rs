//! Runtime partition policy: per-invocation offload decisions from live
//! network + input conditions (paper §3, §5 — "the runtime implements
//! the choice of partition for the current execution conditions").
//!
//! The offline pipeline (profiler → solver → `PartitionDb` → rewriter)
//! picks *candidate* migration points and prices each span; this module
//! is the runtime half: at every `CcStart` the [`PolicyEngine`] compares
//! the expected cost of offloading — forward capsule over the measured
//! uplink, clone execution, reverse capsule over the measured downlink,
//! plus the observed suspend/capture/merge overhead — against the
//! profiled local cost of the span, and answers migrate or local.
//! ThinkAir (arXiv 1105.3232) and Phone2Cloud (arXiv 2008.05851) both
//! show this decision must be re-made at invocation time from measured
//! bandwidth/RTT, not baked into the binary.
//!
//! Invariants (ROADMAP):
//! * Decisions are made *before* suspend/capture, so a local decision
//!   pays zero capture cost (`exec::distributed` enforces the ordering).
//! * The [`NetworkEstimator`] only ever feeds from measured transfers
//!   (the virtual ms actually charged for real wire bytes) and digest
//!   heartbeat roundtrips — never from its own predictions, so there is
//!   no estimate→decision→estimate feedback loop. Because a local
//!   streak starves the estimator, the engine forces one offload
//!   *probe* every `probe_trips` consecutive local decisions.

use std::collections::HashMap;

use crate::appvm::class::Program;
use crate::config::PolicyParams;
use crate::error::{CloneCloudError, Result};
use crate::partitioner::PartitionEntry;

/// Decision override for ablation runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForceMode {
    /// Cost-model decisions (the default).
    Auto,
    /// Always migrate (the seed's hardwired behavior).
    Offload,
    /// Never migrate: the partitioned binary runs like the monolithic
    /// one, and the driver stands the clone down up front.
    Local,
}

impl ForceMode {
    pub fn parse(s: &str) -> Result<ForceMode> {
        match s {
            "auto" => Ok(ForceMode::Auto),
            "offload" => Ok(ForceMode::Offload),
            "local" => Ok(ForceMode::Local),
            other => Err(CloneCloudError::Config(format!(
                "unknown policy.force '{other}' (auto|offload|local)"
            ))),
        }
    }
}

/// The answer at one `CcStart`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Offload,
    Local,
}

/// Exponentially weighted moving average; `alpha` is supplied per
/// update so one engine-wide half-life governs every estimate.
#[derive(Debug, Clone, Copy, Default)]
struct Ewma {
    value: f64,
    seen: bool,
}

impl Ewma {
    fn observe(&mut self, x: f64, alpha: f64) {
        if self.seen {
            self.value += alpha * (x - self.value);
        } else {
            self.value = x;
            self.seen = true;
        }
    }

    fn get(&self) -> Option<f64> {
        if self.seen {
            Some(self.value)
        } else {
            None
        }
    }
}

/// EWMA link estimates from measured transfers: per-direction transfer
/// time *per byte* plus an RTT fed by digest-heartbeat roundtrips.
///
/// The EWMA runs over ms/byte, not bytes/ms: congestion averages
/// arithmetically in the time domain, so one slow transfer moves the
/// estimate as far as one fast transfer does — a throughput EWMA would
/// detect a 10x slowdown an order of magnitude more slowly than a 10x
/// speedup. Until a heartbeat supplies an RTT, the per-transfer latency
/// stays folded into the observed per-byte times — predictions are then
/// slightly pessimistic for larger-than-observed capsules, which the
/// hysteresis margin absorbs.
#[derive(Debug, Clone)]
pub struct NetworkEstimator {
    alpha: f64,
    /// Virtual ms per byte, latency excluded once an RTT is known.
    up_ms_per_byte: Ewma,
    down_ms_per_byte: Ewma,
    /// Measured small-frame roundtrip (both directions' latency).
    rtt: Ewma,
}

impl NetworkEstimator {
    /// `half_life_trips`: observations until an old estimate has half
    /// its weight.
    pub fn new(half_life_trips: f64) -> NetworkEstimator {
        let h = half_life_trips.max(0.1);
        NetworkEstimator {
            alpha: 1.0 - 0.5f64.powf(1.0 / h),
            up_ms_per_byte: Ewma::default(),
            down_ms_per_byte: Ewma::default(),
            rtt: Ewma::default(),
        }
    }

    fn observe(&mut self, bytes: u64, ms: f64, up: bool) {
        if bytes == 0 || ms <= 0.0 {
            return;
        }
        // Strip the one-way latency share when it is known, flooring at
        // 5% of the observation so a latency-dominated transfer never
        // produces a zero/negative bandwidth term.
        let eff_ms = match self.rtt.get() {
            Some(rtt) => (ms - rtt / 2.0).max(ms * 0.05),
            None => ms,
        };
        let ms_per_byte = eff_ms / bytes as f64;
        let alpha = self.alpha;
        if up {
            self.up_ms_per_byte.observe(ms_per_byte, alpha);
        } else {
            self.down_ms_per_byte.observe(ms_per_byte, alpha);
        }
    }

    /// One measured uplink transfer: `bytes` on the wire, `ms` charged.
    pub fn observe_up(&mut self, bytes: u64, ms: f64) {
        self.observe(bytes, ms, true);
    }

    /// One measured downlink transfer.
    pub fn observe_down(&mut self, bytes: u64, ms: f64) {
        self.observe(bytes, ms, false);
    }

    /// One measured small-frame roundtrip (a digest heartbeat).
    pub fn observe_rtt(&mut self, ms: f64) {
        if ms > 0.0 {
            let alpha = self.alpha;
            self.rtt.observe(ms, alpha);
        }
    }

    /// Predicted uplink ms for `bytes`; `None` before any observation.
    pub fn predict_up_ms(&self, bytes: u64) -> Option<f64> {
        self.up_ms_per_byte
            .get()
            .map(|mpb| self.rtt.get().unwrap_or(0.0) / 2.0 + bytes as f64 * mpb)
    }

    /// Predicted downlink ms for `bytes`; `None` before any observation.
    pub fn predict_down_ms(&self, bytes: u64) -> Option<f64> {
        self.down_ms_per_byte
            .get()
            .map(|mpb| self.rtt.get().unwrap_or(0.0) / 2.0 + bytes as f64 * mpb)
    }

    /// Estimated uplink throughput, Mbps (per-byte ms inverted).
    pub fn up_mbps(&self) -> Option<f64> {
        self.up_ms_per_byte.get().map(|mpb| 0.008 / mpb)
    }

    /// Estimated downlink throughput, Mbps.
    pub fn down_mbps(&self) -> Option<f64> {
        self.down_ms_per_byte.get().map(|mpb| 0.008 / mpb)
    }

    /// Measured small-frame roundtrip estimate, ms.
    pub fn rtt_ms(&self) -> Option<f64> {
        self.rtt.get()
    }

    /// One-line rendering for logs and the CLI.
    pub fn describe(&self) -> String {
        let fmt = |v: Option<f64>, unit: &str| match v {
            Some(x) => format!("{x:.2} {unit}"),
            None => "?".to_string(),
        };
        format!(
            "up {}, down {}, rtt {}",
            fmt(self.up_mbps(), "Mbps"),
            fmt(self.down_mbps(), "Mbps"),
            fmt(self.rtt_ms(), "ms"),
        )
    }
}

/// Profiled per-invocation cost of one migratory span (ms, virtual):
/// what the span costs run on the phone vs at the clone.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpanCost {
    pub local_ms: f64,
    pub clone_ms: f64,
}

#[derive(Debug, Clone, Copy)]
struct SpanState {
    cost: SpanCost,
    last: Option<Decision>,
}

/// One decision, as logged for the CLI and the examples.
#[derive(Debug, Clone)]
pub struct DecisionRecord {
    /// Migration-point encounter index within the engine's lifetime.
    pub trip: usize,
    /// Partition-point id (`CcStart` operand).
    pub point: u32,
    pub decision: Decision,
    /// This offload was forced to refresh the estimator, not won on
    /// cost.
    pub probe: bool,
    /// Profiled local cost of the span, if priced.
    pub local_ms: Option<f64>,
    /// The engine's expected offload time at decision time, if it had
    /// enough measurements to compute one.
    pub offload_est_ms: Option<f64>,
    /// Forward-capsule size estimate used (bytes).
    pub fwd_bytes_est: Option<f64>,
    /// Estimator state rendered at decision time.
    pub estimator: String,
}

/// Engine-lifetime decision counters (the per-run view lives in
/// `DistOutcome`).
#[derive(Debug, Clone, Copy, Default)]
pub struct PolicyStats {
    pub offloads: u64,
    pub local_fallbacks: u64,
    pub mispredictions: u64,
    pub probes: u64,
    pub channel_errors: u64,
    /// Marginal decisions raced (local vs clone), and which leg won.
    pub speculations: u64,
    pub speculation_local_wins: u64,
    pub speculation_clone_wins: u64,
}

/// Decision records kept per engine. The engine can outlive many runs;
/// the log exists for CLI/example introspection, so it stops growing at
/// this bound instead of accumulating a record per `CcStart` forever
/// (the counters in [`PolicyStats`] keep counting).
const MAX_DECISION_LOG: usize = 4096;

/// The runtime policy engine: decides migrate-vs-local at every
/// `CcStart` from the estimator's measured link state, the session's
/// capsule-size history, and the profiled span costs. One engine per
/// phone/channel pairing; it may outlive a single run (estimates stay
/// warm across runs exactly like the delta session's baseline).
pub struct PolicyEngine {
    force: ForceMode,
    hysteresis: f64,
    probe_trips: u64,
    degrade_to_local: bool,
    /// Race local-vs-clone when |offload estimate − local cost| lands
    /// under this margin (virtual ms); 0 = never speculate.
    speculation_margin_ms: f64,
    pub estimator: NetworkEstimator,
    spans: HashMap<u32, SpanState>,
    /// Partition-DB shard annotations: points whose span is
    /// data-parallel under the `work(begin, end, shards)` convention,
    /// and how many clone lanes to scatter across.
    span_shards: HashMap<u32, u16>,
    /// Observed forward wire sizes, by capsule flavor: a session holding
    /// a delta baseline predicts the delta size, a cold one the full
    /// size — the input-conditions half of the decision.
    fwd_full_bytes: Ewma,
    fwd_delta_bytes: Ewma,
    rev_bytes: Ewma,
    /// Observed suspend+capture+merge overhead per offload (ms).
    overhead_ms: Ewma,
    alpha: f64,
    consecutive_local: u64,
    trips: usize,
    last_estimate: Option<f64>,
    /// The most recent `decide` was marginal (see `speculation_margin_ms`).
    last_marginal: bool,
    pub log: Vec<DecisionRecord>,
    pub stats: PolicyStats,
}

impl PolicyEngine {
    pub fn from_params(params: &PolicyParams) -> Result<PolicyEngine> {
        let h = params.half_life_trips.max(0.1);
        Ok(PolicyEngine {
            force: ForceMode::parse(&params.force)?,
            hysteresis: params.hysteresis.max(0.0),
            probe_trips: params.probe_trips,
            degrade_to_local: params.degrade_to_local,
            speculation_margin_ms: params.speculation_margin_ms.max(0.0),
            estimator: NetworkEstimator::new(params.half_life_trips),
            spans: HashMap::new(),
            span_shards: HashMap::new(),
            fwd_full_bytes: Ewma::default(),
            fwd_delta_bytes: Ewma::default(),
            rev_bytes: Ewma::default(),
            overhead_ms: Ewma::default(),
            alpha: 1.0 - 0.5f64.powf(1.0 / h),
            consecutive_local: 0,
            trips: 0,
            last_estimate: None,
            last_marginal: false,
            log: Vec::new(),
            stats: PolicyStats::default(),
        })
    }

    /// Cost-model decisions with default parameters.
    pub fn auto() -> PolicyEngine {
        Self::from_params(&PolicyParams::default()).expect("default params parse")
    }

    fn forced(mode: ForceMode) -> PolicyEngine {
        let mut e = Self::auto();
        e.force = mode;
        e
    }

    /// Always-migrate ablation engine.
    pub fn force_offload() -> PolicyEngine {
        Self::forced(ForceMode::Offload)
    }

    /// Never-migrate ablation engine.
    pub fn force_local() -> PolicyEngine {
        Self::forced(ForceMode::Local)
    }

    /// The seed's hardwired behavior for the legacy drivers: every
    /// `CcStart` migrates and channel errors propagate (no degrade).
    pub(crate) fn legacy_offload() -> PolicyEngine {
        Self::force_offload().without_degrade()
    }

    /// Propagate channel errors instead of degrading the span to local
    /// execution.
    pub fn without_degrade(mut self) -> PolicyEngine {
        self.degrade_to_local = false;
        self
    }

    pub fn forces_local(&self) -> bool {
        self.force == ForceMode::Local
    }

    pub fn degrades_to_local(&self) -> bool {
        self.degrade_to_local
    }

    /// Price one partition point (per-invocation profiled costs).
    pub fn set_span(&mut self, point: u32, cost: SpanCost) {
        self.spans.insert(point, SpanState { cost, last: None });
    }

    /// Annotate one partition point as data-parallel: offloads of this
    /// span may scatter across `shards` clone lanes (< 2 clears the
    /// annotation).
    pub fn set_span_shards(&mut self, point: u32, shards: u16) {
        if shards >= 2 {
            self.span_shards.insert(point, shards);
        } else {
            self.span_shards.remove(&point);
        }
    }

    /// The scatter width annotated for this point (`None` = monolithic).
    pub fn span_shards(&self, point: u32) -> Option<u16> {
        self.span_shards.get(&point).copied()
    }

    /// Price every span a partition-DB entry covers, resolving method
    /// names against the *rewritten* binary: each migratory method
    /// carries its point id (`MethodDef::migration_point`), so the
    /// binary itself is the pid ↔ method map.
    pub fn load_entry(&mut self, entry: &PartitionEntry, program: &Program) -> Result<()> {
        for (i, name) in entry.migrate.iter().enumerate() {
            let (c, m) = name.split_once('.').ok_or_else(|| {
                CloneCloudError::partitioner(format!("bad method name '{name}'"))
            })?;
            let mref = program.resolve(c, m)?;
            if let Some(pid) = program.method(mref).migration_point {
                let local_ms = entry.span_local_ms.get(i).copied().unwrap_or(0.0);
                let clone_ms = entry.span_clone_ms.get(i).copied().unwrap_or(0.0);
                if local_ms > 0.0 {
                    self.set_span(pid, SpanCost { local_ms, clone_ms });
                }
                // Honor a DB shard annotation only when the rewritten
                // method actually matches the scatter convention — a
                // stale annotation must never scatter a monolithic span.
                let shards = entry.span_shards.get(i).copied().unwrap_or(0);
                if shards >= 2 && crate::partitioner::shard_shaped(program, mref) {
                    self.set_span_shards(pid, shards);
                }
            }
        }
        Ok(())
    }

    /// The expected offload time computed by the most recent
    /// [`PolicyEngine::decide`], if it had enough measurements.
    pub fn last_offload_estimate(&self) -> Option<f64> {
        self.last_estimate
    }

    fn cost_decision(
        &self,
        point: u32,
        has_baseline: bool,
        est_out: &mut Option<f64>,
        fwd_out: &mut Option<f64>,
    ) -> Decision {
        // Unpriced span or cold estimator: fall back to the static
        // choice — the partition DB picked this binary for offload.
        let Some(span) = self.spans.get(&point) else {
            return Decision::Offload;
        };
        // Size the forward capsule from the flavor the session will
        // actually send. A baseline-holding session about to send its
        // FIRST delta has no delta-size history yet — pricing it with
        // the full-capture size would wildly overestimate, so that case
        // also falls back to the static choice.
        let fwd = if has_baseline {
            self.fwd_delta_bytes.get()
        } else {
            self.fwd_full_bytes.get()
        };
        let Some(fwd) = fwd else {
            return Decision::Offload;
        };
        *fwd_out = Some(fwd);
        let rev = self.rev_bytes.get().unwrap_or(fwd);
        let (Some(up_ms), Some(down_ms)) = (
            self.estimator.predict_up_ms(fwd as u64),
            self.estimator.predict_down_ms(rev as u64),
        ) else {
            return Decision::Offload;
        };
        let est = self.overhead_ms.get().unwrap_or(0.0) + up_ms + span.cost.clone_ms + down_ms;
        *est_out = Some(est);
        // Hysteresis: the side currently losing must win by the margin
        // before the decision flips.
        let margin = 1.0 + self.hysteresis;
        let offload_wins = match span.last {
            Some(Decision::Local) => est * margin <= span.cost.local_ms,
            _ => est <= span.cost.local_ms * margin,
        };
        if offload_wins {
            Decision::Offload
        } else {
            Decision::Local
        }
    }

    /// Decide migrate-vs-local for one `CcStart`, BEFORE any
    /// suspend/capture work. `has_baseline` selects which capsule-size
    /// history prices the forward transfer (delta vs full capture).
    pub fn decide(&mut self, point: u32, has_baseline: bool) -> Decision {
        let trip = self.trips;
        self.trips += 1;
        let mut est = None;
        let mut fwd = None;
        let mut probe = false;
        let decision = match self.force {
            ForceMode::Offload => Decision::Offload,
            ForceMode::Local => Decision::Local,
            ForceMode::Auto => {
                let computed = self.cost_decision(point, has_baseline, &mut est, &mut fwd);
                if computed == Decision::Local
                    && self.probe_trips > 0
                    && self.consecutive_local >= self.probe_trips
                {
                    probe = true;
                    Decision::Offload
                } else {
                    computed
                }
            }
        };
        self.last_estimate = est;
        match decision {
            Decision::Offload => {
                self.consecutive_local = 0;
                self.stats.offloads += 1;
                if probe {
                    self.stats.probes += 1;
                }
            }
            Decision::Local => {
                self.consecutive_local += 1;
                self.stats.local_fallbacks += 1;
            }
        }
        let local_ms = self.spans.get(&point).map(|s| s.cost.local_ms);
        // Marginal call: both sides priced and within the speculation
        // margin of each other — the cost model has no real confidence,
        // so the driver may race the two legs instead of trusting it.
        self.last_marginal = self.speculation_margin_ms > 0.0
            && matches!((est, local_ms),
                (Some(e), Some(l)) if l > 0.0 && (e - l).abs() < self.speculation_margin_ms);
        if let Some(s) = self.spans.get_mut(&point) {
            s.last = Some(decision);
        }
        if self.log.len() < MAX_DECISION_LOG {
            self.log.push(DecisionRecord {
                trip,
                point,
                decision,
                probe,
                local_ms,
                offload_est_ms: est,
                fwd_bytes_est: fwd,
                estimator: self.estimator.describe(),
            });
        }
        decision
    }

    /// Whether the most recent [`PolicyEngine::decide`] was marginal:
    /// offload estimate and profiled local cost within the speculation
    /// margin. The driver races the two legs and commits the first
    /// finisher instead of trusting a coin-flip prediction.
    pub fn speculation_candidate(&self) -> bool {
        self.last_marginal
    }

    /// Set the speculation margin directly (builders/tests; the config
    /// path goes through [`PolicyEngine::from_params`]).
    pub fn with_speculation_margin(mut self, ms: f64) -> PolicyEngine {
        self.speculation_margin_ms = ms.max(0.0);
        self
    }

    /// Record the outcome of one local-vs-clone race. The loser's leg
    /// also feeds `score_*` as usual, so races sharpen the estimator
    /// with a measured sample of BOTH sides.
    pub fn note_speculation(&mut self, local_won: bool) {
        self.stats.speculations += 1;
        if local_won {
            self.stats.speculation_local_wins += 1;
        } else {
            self.stats.speculation_clone_wins += 1;
        }
    }

    /// Feed one measured forward transfer (wire bytes + virtual ms
    /// charged), tagged with the capsule flavor that produced it.
    pub fn observe_forward(&mut self, bytes: u64, ms: f64, delta: bool) {
        let alpha = self.alpha;
        if delta {
            self.fwd_delta_bytes.observe(bytes as f64, alpha);
        } else {
            self.fwd_full_bytes.observe(bytes as f64, alpha);
        }
        self.estimator.observe_up(bytes, ms);
    }

    /// Feed one measured reverse transfer.
    pub fn observe_reverse(&mut self, bytes: u64, ms: f64) {
        let alpha = self.alpha;
        self.rev_bytes.observe(bytes as f64, alpha);
        self.estimator.observe_down(bytes, ms);
    }

    /// Feed the measured suspend+capture+merge overhead of one offload.
    pub fn observe_overhead(&mut self, ms: f64) {
        let alpha = self.alpha;
        self.overhead_ms.observe(ms, alpha);
    }

    /// Feed one measured heartbeat roundtrip.
    pub fn observe_rtt(&mut self, ms: f64) {
        self.estimator.observe_rtt(ms);
    }

    /// Score a completed offload against the profiled local cost:
    /// decided-offload-but-local-would-have-won. Returns true on
    /// misprediction.
    pub fn score_offload(&mut self, point: u32, actual_ms: f64) -> bool {
        let Some(s) = self.spans.get(&point) else {
            return false;
        };
        let mis = s.cost.local_ms > 0.0 && s.cost.local_ms < actual_ms;
        if mis {
            self.stats.mispredictions += 1;
        }
        mis
    }

    /// Score a completed local span against the offload estimate made
    /// at decision time: decided-local-but-offload-would-have-won.
    pub fn score_local(&mut self, actual_ms: f64, predicted_offload_ms: Option<f64>) -> bool {
        let mis = matches!(predicted_offload_ms, Some(p) if p < actual_ms);
        if mis {
            self.stats.mispredictions += 1;
        }
        mis
    }

    /// A failed offload roundtrip was degraded to local execution:
    /// reclassify the decision in the engine-lifetime stats.
    pub fn note_degrade(&mut self) {
        self.stats.offloads = self.stats.offloads.saturating_sub(1);
        self.stats.local_fallbacks += 1;
        self.stats.channel_errors += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fed_engine(up_rate_bpms: f64, down_rate_bpms: f64) -> PolicyEngine {
        let mut e = PolicyEngine::auto();
        // Two observations per direction so the EWMA is warm.
        for _ in 0..2 {
            e.observe_forward(10_000, 10_000.0 / up_rate_bpms, false);
            e.observe_reverse(2_000, 2_000.0 / down_rate_bpms);
        }
        e
    }

    #[test]
    fn estimator_tracks_rate_shifts() {
        let mut est = NetworkEstimator::new(1.0);
        assert!(est.predict_up_ms(1000).is_none(), "cold estimator");
        est.observe_up(10_000, 100.0); // 100 B/ms
        let fast = est.predict_up_ms(10_000).unwrap();
        assert!((fast - 100.0).abs() < 1e-6);
        // The link degrades 10x; a couple of observations converge.
        est.observe_up(10_000, 1000.0);
        est.observe_up(10_000, 1000.0);
        let slow = est.predict_up_ms(10_000).unwrap();
        assert!(slow > 3.0 * fast, "rate shift tracked: {fast} -> {slow}");
    }

    #[test]
    fn rtt_excluded_from_bandwidth_once_known() {
        let mut est = NetworkEstimator::new(1.0);
        est.observe_rtt(100.0);
        est.observe_up(10_000, 150.0); // 50 ms latency + 100 ms wire
        let p = est.predict_up_ms(10_000).unwrap();
        // 50 (rtt/2) + 10_000 / (10_000/100) = 150.
        assert!((p - 150.0).abs() < 1e-6, "{p}");
        assert!(est.rtt_ms().unwrap() > 99.0);
    }

    #[test]
    fn cold_engine_keeps_static_offload_choice() {
        let mut e = PolicyEngine::auto();
        e.set_span(0, SpanCost { local_ms: 100.0, clone_ms: 5.0 });
        assert_eq!(e.decide(0, false), Decision::Offload, "no measurements yet");
        assert_eq!(e.stats.offloads, 1);
    }

    #[test]
    fn fast_link_offloads_slow_link_goes_local() {
        // 300 B/ms up (2.4 Mbps): offload ≈ 10_000/300 + 5 + small ≈ 40 ms
        // against 600 ms local.
        let mut fast = fed_engine(300.0, 300.0);
        fast.set_span(0, SpanCost { local_ms: 600.0, clone_ms: 5.0 });
        assert_eq!(fast.decide(0, false), Decision::Offload);

        // 3 B/ms up: offload ≈ 10_000/3 ≈ 3_300 ms against 600 ms local.
        let mut slow = fed_engine(3.0, 3.0);
        slow.set_span(0, SpanCost { local_ms: 600.0, clone_ms: 5.0 });
        assert_eq!(slow.decide(0, false), Decision::Local);
        assert_eq!(slow.stats.local_fallbacks, 1);
        assert!(slow.log.last().unwrap().offload_est_ms.unwrap() > 600.0);
    }

    #[test]
    fn probe_breaks_local_streaks() {
        let mut e = fed_engine(3.0, 3.0);
        e.probe_trips = 3;
        e.set_span(0, SpanCost { local_ms: 100.0, clone_ms: 5.0 });
        let decisions: Vec<Decision> = (0..4).map(|_| e.decide(0, false)).collect();
        assert_eq!(
            decisions,
            vec![
                Decision::Local,
                Decision::Local,
                Decision::Local,
                Decision::Offload
            ],
            "the 4th decision is a forced probe"
        );
        assert_eq!(e.stats.probes, 1);
        assert!(e.log[3].probe);
    }

    #[test]
    fn forced_modes_override_cost_model() {
        let mut local = PolicyEngine::force_local();
        local.set_span(0, SpanCost { local_ms: 1e9, clone_ms: 0.0 });
        assert!(local.forces_local());
        assert_eq!(local.decide(0, false), Decision::Local);

        let mut off = fed_engine(0.001, 0.001);
        off.force = ForceMode::Offload;
        off.set_span(0, SpanCost { local_ms: 0.001, clone_ms: 0.0 });
        assert_eq!(off.decide(0, false), Decision::Offload);
        assert!(ForceMode::parse("psychic").is_err());
    }

    #[test]
    fn scoring_counts_both_misprediction_kinds() {
        let mut e = PolicyEngine::auto();
        e.set_span(0, SpanCost { local_ms: 100.0, clone_ms: 5.0 });
        assert!(e.score_offload(0, 500.0), "local would have won");
        assert!(!e.score_offload(0, 50.0), "offload was right");
        assert!(e.score_local(500.0, Some(100.0)), "offload would have won");
        assert!(!e.score_local(50.0, Some(100.0)));
        assert!(!e.score_local(500.0, None), "no estimate, no verdict");
        assert_eq!(e.stats.mispredictions, 2);
    }

    #[test]
    fn marginal_decisions_become_speculation_candidates() {
        // fed_engine(100, 100): est = 10_000/100 + clone + 2_000/100
        // = 120 ms + clone_ms.
        let mut e = fed_engine(100.0, 100.0).with_speculation_margin(50.0);
        e.set_span(0, SpanCost { local_ms: 100.0, clone_ms: 0.0 });
        e.decide(0, false);
        assert!(e.speculation_candidate(), "|120 - 100| < 50");

        e.set_span(1, SpanCost { local_ms: 600.0, clone_ms: 0.0 });
        e.decide(1, false);
        assert!(!e.speculation_candidate(), "|120 - 600| is a clear call");

        let mut off = fed_engine(100.0, 100.0); // margin 0: disabled
        off.set_span(0, SpanCost { local_ms: 100.0, clone_ms: 0.0 });
        off.decide(0, false);
        assert!(!off.speculation_candidate());

        let mut cold = PolicyEngine::auto().with_speculation_margin(50.0);
        cold.set_span(0, SpanCost { local_ms: 100.0, clone_ms: 0.0 });
        cold.decide(0, false);
        assert!(!cold.speculation_candidate(), "no estimate, no race");

        e.note_speculation(true);
        e.note_speculation(false);
        assert_eq!(e.stats.speculations, 2);
        assert_eq!(e.stats.speculation_local_wins, 1);
        assert_eq!(e.stats.speculation_clone_wins, 1);
    }

    #[test]
    fn hysteresis_resists_flapping() {
        let mut e = fed_engine(100.0, 100.0);
        e.hysteresis = 0.5;
        // Offload estimate lands just above local cost; a prior Local
        // decision holds unless offload wins by the 1.5x margin.
        e.set_span(0, SpanCost { local_ms: 100.0, clone_ms: 0.0 });
        e.spans.get_mut(&0).unwrap().last = Some(Decision::Local);
        // fwd 10_000 B at 100 B/ms => 100 ms + rev 2_000/100 = 20 ms:
        // est 120 ms; 120 * 1.5 > 100 -> stays Local.
        assert_eq!(e.decide(0, false), Decision::Local);
        // From an Offload history the same numbers keep offloading only
        // if est <= local * 1.5 = 150: est 120 -> Offload.
        e.spans.get_mut(&0).unwrap().last = Some(Decision::Offload);
        e.consecutive_local = 0;
        assert_eq!(e.decide(0, false), Decision::Offload);
    }
}
