//! Monolithic execution: the paper's status quo (Table 1 cols 3-4).

use crate::appvm::interp::{run_thread, ExecHooks, NoHooks, RunExit};
use crate::appvm::process::Process;
use crate::appvm::value::Value;
use crate::error::{CloneCloudError, Result};

/// Outcome of a monolithic run.
#[derive(Debug, Clone)]
pub struct MonoOutcome {
    /// Virtual execution time (ms).
    pub virtual_ms: f64,
    /// `main`'s return value, if any.
    pub result: Option<Value>,
    /// Wall-clock seconds (real PJRT compute + interpretation).
    pub wall_s: f64,
    pub instrs: u64,
}

/// Run the app's entry to completion on `p`. Partition points, if the
/// binary has them, are skipped (the "Local" policy).
pub fn run_monolithic(p: &mut Process) -> Result<MonoOutcome> {
    run_monolithic_hooked(p, &mut NoHooks)
}

/// Monolithic run with observation hooks (used by the profiler path).
pub fn run_monolithic_hooked<H: ExecHooks>(p: &mut Process, hooks: &mut H) -> Result<MonoOutcome> {
    let wall0 = std::time::Instant::now();
    let entry = p.program.entry()?;
    let tid = p.spawn_thread(entry, &[])?;
    let result = loop {
        match run_thread(p, tid, hooks, u64::MAX)? {
            RunExit::Completed(v) => break v,
            RunExit::MigrationPoint { .. } | RunExit::ReintegrationPoint { .. } => continue,
            RunExit::OutOfFuel => {
                return Err(CloneCloudError::vm("monolithic run out of fuel"))
            }
        }
    };
    Ok(MonoOutcome {
        virtual_ms: p.clock.now_ms(),
        result,
        wall_s: wall0.elapsed().as_secs_f64(),
        instrs: p.metrics.instrs,
    })
}
