//! Execution drivers: the lifecycle of §4.
//!
//! * [`monolithic`] — status-quo execution of an (unmodified or
//!   partitioned-but-local) binary on one device.
//! * [`distributed`] — the CloneCloud run: launch the partitioned binary,
//!   migrate at CcStart, execute at the clone, reintegrate at CcStop,
//!   merge, continue — with virtual network time charged from the real
//!   byte counts.

pub mod distributed;
pub mod monolithic;

pub use distributed::{run_distributed, DistOutcome, FarmClone, InlineClone};
pub use monolithic::{run_monolithic, run_monolithic_hooked, MonoOutcome};
