//! Execution drivers: the lifecycle of §4.
//!
//! * [`monolithic`] — status-quo execution of an (unmodified or
//!   partitioned-but-local) binary on one device.
//! * [`policy`] — the runtime partition policy: a [`PolicyEngine`]
//!   decides migrate-vs-local at every `CcStart` from EWMA link
//!   estimates fed by the measured transfers and the profiled span
//!   costs, with forced-offload/forced-local ablation modes.
//! * [`distributed`] — the CloneCloud run: launch the partitioned binary,
//!   ask the policy at CcStart, migrate (or continue locally), execute at
//!   the clone, reintegrate at CcStop, merge, continue — with virtual
//!   network time charged from the real byte counts.
//!   `run_distributed_session` adds delta migration on top (epoch-based
//!   dirty tracking, `NeedFull` full-capture fallback);
//!   `run_distributed_with` sweeps the network per migration trip.
//!   Spans annotated with `span_shards >= 2` scatter/gather: one full
//!   capture fans across N clone lanes as sub-job frames and the N
//!   disjoint reverse deltas merge against the single baseline (an
//!   overlap degrades to the monolithic offload, never corrupts);
//!   marginal decisions under `policy.speculation_margin_ms` race the
//!   local interpretation against the offload and commit whichever
//!   finishes first on the virtual clock.
//! * [`faults`] — [`FaultInjectChannel`], a channel wrapper that kills
//!   the link at the Nth frame boundary (the fault-matrix tests drive
//!   degrade-to-local and `NeedFull` recovery through it), and
//!   [`HostilePeerChannel`], a wrapper whose peer answers maliciously —
//!   truncated, bit-flipped, replayed, oversize-claiming, or garbage
//!   replies (the hostile-peer matrix drives clean degradation through
//!   it).

pub mod distributed;
pub mod faults;
pub mod monolithic;
pub mod policy;

pub use faults::{FaultInjectChannel, HostileBehavior, HostilePeerChannel};

pub use distributed::{
    delta_statics_workload_src, delta_workload_expected, delta_workload_src, run_distributed,
    run_distributed_policy, run_distributed_session, run_distributed_traced,
    run_distributed_traced_with, run_distributed_with, scatter_conflict_workload_src,
    scatter_workload_expected, scatter_workload_src, CloneChannel, DistOutcome, FarmClone,
    InlineClone,
};
pub use monolithic::{run_monolithic, run_monolithic_hooked, MonoOutcome};
pub use policy::{
    Decision, DecisionRecord, ForceMode, NetworkEstimator, PolicyEngine, PolicyStats, SpanCost,
};
