//! Execution drivers: the lifecycle of §4.
//!
//! * [`monolithic`] — status-quo execution of an (unmodified or
//!   partitioned-but-local) binary on one device.
//! * [`distributed`] — the CloneCloud run: launch the partitioned binary,
//!   migrate at CcStart, execute at the clone, reintegrate at CcStop,
//!   merge, continue — with virtual network time charged from the real
//!   byte counts. `run_distributed_session` adds delta migration on top
//!   (epoch-based dirty tracking, `NeedFull` full-capture fallback).

pub mod distributed;
pub mod monolithic;

pub use distributed::{
    delta_statics_workload_src, delta_workload_expected, delta_workload_src, run_distributed,
    run_distributed_session, CloneChannel, DistOutcome, FarmClone, InlineClone,
};
pub use monolithic::{run_monolithic, run_monolithic_hooked, MonoOutcome};
