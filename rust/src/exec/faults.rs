//! Fault-injection and hostile-peer channel wrappers for tests and
//! resilience drills.
//!
//! [`FaultInjectChannel`] wraps any [`CloneChannel`] and kills the link
//! at the Nth frame boundary: frames are counted in wire order — forward
//! capsule, reverse capsule, heartbeat probe, heartbeat ack — and once
//! the budget is spent every operation fails with a transport error,
//! exactly like a dead TCP peer. Because the cut can land *between* the
//! halves of one roundtrip, the inner clone may have executed (and
//! mutated its slot state, baseline and dictionary included) while the
//! phone never hears back — the half-applied-state shape the
//! degrade-to-local and `NeedFull`-recovery paths must absorb.
//!
//! The fault-matrix tests sweep N across a whole session and assert
//! that, under a degrading policy engine, every cut point still
//! completes the run locally with the error surfaced in
//! `DistOutcome::channel_errors` — and that the legacy
//! `run_distributed_session` wrapper still fails fast. No panics, no
//! half-applied merges.

use crate::error::{CloneCloudError, Result};
use crate::migration::MobileSession;
use crate::nodemanager::{Codec, HeartbeatOutcome, TransferBytes};
use crate::util::rng::Rng;

use super::distributed::CloneChannel;

/// A [`CloneChannel`] that dies at a chosen frame boundary.
pub struct FaultInjectChannel<C: CloneChannel> {
    inner: C,
    /// Frames allowed across the link before it dies (`u64::MAX` =
    /// never).
    kill_after: u64,
    frames: u64,
    dead: bool,
}

impl<C: CloneChannel> FaultInjectChannel<C> {
    /// Wrap `inner`; the link dies once `kill_after` frames have
    /// crossed (the frame that would exceed the budget is lost).
    pub fn new(inner: C, kill_after: u64) -> FaultInjectChannel<C> {
        FaultInjectChannel {
            inner,
            kill_after,
            frames: 0,
            dead: false,
        }
    }

    /// Frames that actually crossed before the cut.
    pub fn frames_crossed(&self) -> u64 {
        self.frames.min(self.kill_after)
    }

    /// Whether the injected fault has fired.
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Access the wrapped channel (e.g. to inspect the clone state
    /// after a cut).
    pub fn inner(&self) -> &C {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut C {
        &mut self.inner
    }

    /// Unwrap the (possibly half-advanced) inner channel, e.g. to drive
    /// a recovery session over the same clone after a cut.
    pub fn into_inner(self) -> C {
        self.inner
    }

    /// Account one frame; errors if it would cross the kill boundary.
    fn cross(&mut self, what: &str) -> Result<()> {
        if self.dead {
            return Err(CloneCloudError::Transport(format!(
                "injected fault: link down ({what})"
            )));
        }
        self.frames += 1;
        if self.frames > self.kill_after {
            self.dead = true;
            return Err(CloneCloudError::Transport(format!(
                "injected fault: link killed at frame {} ({what})",
                self.frames
            )));
        }
        Ok(())
    }
}

impl<C: CloneChannel> CloneChannel for FaultInjectChannel<C> {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        // The forward frame crosses (or dies) first...
        self.cross("forward capsule")?;
        let reply = self.inner.roundtrip(forward)?;
        // ...then the reverse frame. When this one is cut, the clone has
        // already executed and re-baselined — the phone-side recovery
        // must not assume symmetric state.
        self.cross("reverse capsule")?;
        Ok(reply)
    }

    fn delta_capable(&self) -> bool {
        self.inner.delta_capable()
    }

    fn disarm_delta(&mut self) {
        self.inner.disarm_delta()
    }

    fn codec(&self) -> Codec {
        self.inner.codec()
    }

    fn dict_capable(&self) -> bool {
        self.inner.dict_capable()
    }

    fn heartbeat(&mut self, session: &mut MobileSession) -> Result<HeartbeatOutcome> {
        self.cross("heartbeat probe")?;
        let outcome = self.inner.heartbeat(session)?;
        if outcome != HeartbeatOutcome::Unsupported {
            self.cross("heartbeat ack")?;
        }
        Ok(outcome)
    }

    fn record_policy(&mut self, offloads: u64, local: u64, mispredictions: u64) {
        self.inner.record_policy(offloads, local, mispredictions)
    }

    fn scatter_capable(&self) -> bool {
        self.inner.scatter_capable()
    }

    fn scatter(&mut self, frames: Vec<Vec<u8>>) -> Result<(Vec<Vec<u8>>, TransferBytes)> {
        // Every sub-job frame crosses before the exchange, and every
        // sub-result after it — so a cut can strand any prefix of the
        // fan-out on the wire, or kill the gather after some lanes
        // already executed. Either way the driver must degrade with the
        // phone untouched.
        for i in 0..frames.len() {
            self.cross(&format!("scatter sub-job {i}"))?;
        }
        let (replies, total) = self.inner.scatter(frames)?;
        for i in 0..replies.len() {
            self.cross(&format!("scatter sub-result {i}"))?;
        }
        Ok((replies, total))
    }
}

/// The scripted misbehaviors a [`HostilePeerChannel`] applies to reply
/// frames — the malicious-endpoint half of the wire-robustness matrix
/// (`tests/hostile_peer.rs`). Each one models a concrete attack or
/// corruption shape a phone can meet on a real link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostileBehavior {
    /// Deliver replies untouched (the control row of the matrix).
    Honest,
    /// Cut the reply short — a truncated frame.
    TruncateReply,
    /// Flip one bit somewhere in the reply.
    BitFlipReply,
    /// Answer with the PREVIOUS round's reply, verbatim — a replayed
    /// capsule (stale clock, stale baseline epoch, stale mappings).
    ReplayPreviousReply,
    /// Append garbage after the valid reply (trailing bytes).
    AppendGarbage,
    /// Replace the reply with pure random garbage.
    GarbageReply,
    /// Rewrite a 32-bit word inside the reply with an all-ones value —
    /// an oversize length/count claim aimed at the decoder's
    /// pre-validation allocations.
    OversizeClaim,
    /// Claim `NeedFull` on every frame, forever — a peer lying about
    /// its baseline to force useless full recaptures.
    AlwaysNeedFull,
}

/// A [`CloneChannel`] whose peer executes honestly but answers
/// maliciously: the wrapped channel's replies are tampered with per
/// [`HostileBehavior`] before the driver sees them. Deterministic for a
/// seed, so any matrix failure replays exactly.
///
/// The driver contract under every behavior: no panic, no half-applied
/// merge, and — under a degrading policy engine — the span completes
/// locally with the error surfaced in `DistOutcome::channel_errors`.
pub struct HostilePeerChannel<C: CloneChannel> {
    inner: C,
    behavior: HostileBehavior,
    rng: Rng,
    prev_reply: Option<Vec<u8>>,
    /// Reply frames tampered with so far.
    tampered: u64,
}

impl<C: CloneChannel> HostilePeerChannel<C> {
    pub fn new(inner: C, behavior: HostileBehavior, seed: u64) -> HostilePeerChannel<C> {
        HostilePeerChannel {
            inner,
            behavior,
            rng: Rng::new(seed),
            prev_reply: None,
            tampered: 0,
        }
    }

    /// Reply frames tampered with so far.
    pub fn tampered(&self) -> u64 {
        self.tampered
    }

    /// Access the wrapped (honest) channel.
    pub fn inner(&self) -> &C {
        &self.inner
    }

    pub fn into_inner(self) -> C {
        self.inner
    }

    fn corrupt(&mut self, reply: Vec<u8>) -> Vec<u8> {
        match self.behavior {
            HostileBehavior::Honest | HostileBehavior::AlwaysNeedFull => reply,
            HostileBehavior::TruncateReply => {
                self.tampered += 1;
                let keep = self.rng.index(reply.len().max(1));
                reply[..keep].to_vec()
            }
            HostileBehavior::BitFlipReply => {
                self.tampered += 1;
                let mut b = reply;
                if !b.is_empty() {
                    let i = self.rng.index(b.len());
                    b[i] ^= 1 << self.rng.index(8);
                }
                b
            }
            HostileBehavior::ReplayPreviousReply => {
                // The first exchange has nothing to replay; pass it
                // through and start lying on the second.
                let out = match self.prev_reply.take() {
                    Some(prev) => {
                        self.tampered += 1;
                        prev
                    }
                    None => reply.clone(),
                };
                self.prev_reply = Some(reply);
                out
            }
            HostileBehavior::AppendGarbage => {
                self.tampered += 1;
                let mut b = reply;
                let n = 1 + self.rng.index(32);
                for _ in 0..n {
                    b.push(self.rng.byte());
                }
                b
            }
            HostileBehavior::GarbageReply => {
                self.tampered += 1;
                let mut b = vec![0u8; reply.len().max(8)];
                self.rng.fill_bytes(&mut b);
                b
            }
            HostileBehavior::OversizeClaim => {
                self.tampered += 1;
                let mut b = reply;
                if b.len() >= 4 {
                    let i = self.rng.index(b.len() - 3);
                    b[i..i + 4].copy_from_slice(&u32::MAX.to_be_bytes());
                }
                b
            }
        }
    }
}

impl<C: CloneChannel> CloneChannel for HostilePeerChannel<C> {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        if self.behavior == HostileBehavior::AlwaysNeedFull {
            self.tampered += 1;
            return Err(CloneCloudError::need_full(
                "hostile peer claims a baseline mismatch on every frame",
            ));
        }
        let (reply, t) = self.inner.roundtrip(forward)?;
        Ok((self.corrupt(reply), t))
    }

    fn delta_capable(&self) -> bool {
        self.inner.delta_capable()
    }

    fn disarm_delta(&mut self) {
        self.inner.disarm_delta()
    }

    fn codec(&self) -> Codec {
        self.inner.codec()
    }

    fn dict_capable(&self) -> bool {
        self.inner.dict_capable()
    }

    fn heartbeat(&mut self, session: &mut MobileSession) -> Result<HeartbeatOutcome> {
        self.inner.heartbeat(session)
    }

    fn record_policy(&mut self, offloads: u64, local: u64, mispredictions: u64) {
        self.inner.record_policy(offloads, local, mispredictions)
    }

    fn trace_capable(&self) -> bool {
        self.inner.trace_capable()
    }

    fn scatter_capable(&self) -> bool {
        self.inner.scatter_capable()
    }

    fn scatter(&mut self, frames: Vec<Vec<u8>>) -> Result<(Vec<Vec<u8>>, TransferBytes)> {
        if self.behavior == HostileBehavior::AlwaysNeedFull {
            self.tampered += 1;
            return Err(CloneCloudError::need_full(
                "hostile peer claims a baseline mismatch on every frame",
            ));
        }
        let (replies, t) = self.inner.scatter(frames)?;
        let replies = replies.into_iter().map(|r| self.corrupt(r)).collect();
        Ok((replies, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodemanager::TransferBytes;

    struct EchoChannel;
    impl CloneChannel for EchoChannel {
        fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
            let up = forward.len() as u64;
            Ok((forward, TransferBytes { up, down: up }))
        }
    }

    #[test]
    fn kills_at_the_exact_frame_boundary_and_stays_dead() {
        // Budget 3: roundtrip 1 crosses both frames, roundtrip 2 sends
        // its forward (frame 3) and loses the reverse (frame 4).
        let mut ch = FaultInjectChannel::new(EchoChannel, 3);
        ch.roundtrip(vec![1]).unwrap();
        let err = ch.roundtrip(vec![2]).unwrap_err().to_string();
        assert!(err.contains("frame 4"), "{err}");
        assert!(ch.is_dead());
        assert_eq!(ch.frames_crossed(), 3);
        // Dead forever after.
        let err = ch.roundtrip(vec![3]).unwrap_err().to_string();
        assert!(err.contains("link down"), "{err}");
    }

    #[test]
    fn zero_budget_kills_the_first_forward() {
        let mut ch = FaultInjectChannel::new(EchoChannel, 0);
        let err = ch.roundtrip(vec![9]).unwrap_err().to_string();
        assert!(err.contains("forward"), "{err}");
    }
}
