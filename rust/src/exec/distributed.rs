//! The CloneCloud distributed run (paper §4, Figure 7).
//!
//! The phone process executes the partitioned binary. At each `CcStart`
//! the runtime [`PolicyEngine`] (`exec::policy`) decides migrate-vs-local
//! for *this* invocation under the *current* (measured) network and
//! input conditions. A local decision simply continues the thread — the
//! span runs on the phone at zero capture cost. A migrate decision
//! suspends and captures the thread, charges the uplink for the real
//! capture bytes, and hands off to the clone channel; the clone executes
//! to `CcStop`, the reverse capture rides the downlink, and the merge
//! resumes the thread on the phone. Every decision and its after-the-fact
//! score (`offloads`, `local_fallbacks`, `mispredictions`) lands in
//! [`DistOutcome`].
//!
//! Three clone channels: [`InlineClone`] (clone process owned by the
//! caller — deterministic, used by benches), any
//! `nodemanager::NodeManager` over a real transport (TCP loopback in the
//! examples), and [`FarmClone`] (a session on the multi-tenant clone
//! farm, `crate::farm` — N phones multiplexed over M workers). Virtual
//! time: the phone clock carries suspend + capture + uplink; the clone
//! continues from the received timestamp; the phone then adopts the
//! clone's finish time plus downlink + merge.
//!
//! **Delta migration**: [`run_distributed_session`] threads a
//! [`MobileSession`] through the run. After first contact, repeat
//! migrations ship only the mutated working set (epoch-based dirty
//! tracking, `migration::delta`); a clone that lost its baseline answers
//! `NeedFull` and the driver transparently falls back to a full capture.
//! The session can outlive a single run — keep it (and the channel)
//! around and repeat offloads from the same phone keep paying O(dirty)
//! instead of O(heap). [`run_distributed`] is the session-less wrapper:
//! full captures every time, the paper's original behavior.

use crate::appvm::interp::{run_thread, NoHooks, RunExit};
use crate::appvm::process::Process;
use crate::appvm::thread::ThreadStatus;
use crate::appvm::value::Value;
use crate::appvm::ExecTier;
use crate::config::{CostParams, ExecTierKind, NetworkProfile};
use crate::error::{CloneCloudError, Result};
use crate::migration::{
    collect_slot_garbage, scatter_range, shard_capsule, Capsule, CloneSession, DeltaPacket,
    DictMode, DictRead, MigrationPhases, Migrator, MobileSession, CAPSULE_CLOCK_OFFSET,
};
use crate::nodemanager::{
    decode_sub_result, execute_migration, open_frame, patch_frame_payload, seal_frame,
    seal_frame_keep_head, CloneServeStats, Codec, HeartbeatOutcome, NodeManager, SubJobFrame,
    TransferBytes, Transport, SUB_JOB_PAYLOAD_OFFSET,
};
use crate::trace::{
    self, Counter, DecisionEvent, Mark, Phase, TraceCtx, Tracer, FLAG_WANT_CLONE_EVENTS,
};
use crate::util::bytes::WireWriter;

use super::policy::{Decision, PolicyEngine};

pub use crate::farm::FarmClone;

/// Approximate wire size of a digest heartbeat probe and its ack: the
/// virtual roundtrip charged for one heartbeat, which is also the
/// estimator's measured RTT sample.
const HEARTBEAT_PROBE_BYTES: u64 = 64;
const HEARTBEAT_ACK_BYTES: u64 = 16;

/// Where the offloaded span runs.
pub trait CloneChannel {
    /// Process one forward capsule; return the reverse capsule bytes (the
    /// clone's virtual finish time is inside the capsule). A typed
    /// `NeedFull` error asks the driver to resend a full capture.
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)>;

    /// Whether this channel negotiated delta capsules. The driver
    /// disables a session's delta path when the channel cannot carry it.
    fn delta_capable(&self) -> bool {
        false
    }

    /// Stand down the clone side's delta emission. The driver calls this
    /// when its `MobileSession` is disabled, so an armed channel cannot
    /// send back reverse deltas the mobile cannot merge.
    fn disarm_delta(&mut self) {}

    /// The frame codec this channel negotiated: the driver seals forward
    /// capsules with it (and charges the uplink for the sealed bytes).
    fn codec(&self) -> Codec {
        Codec::None
    }

    /// Whether this channel negotiated the session string dictionary
    /// (`CAP_SESSION_DICT`). When true, every capsule on this channel
    /// carries the self-describing dictionary mode byte; the driver
    /// encodes against the session's replica (or the inline table when
    /// the session has the dictionary disabled).
    fn dict_capable(&self) -> bool {
        false
    }

    /// Probe the clone's session baseline with a digest heartbeat. A
    /// `Divergent` answer must drop the mobile baseline (the impl does),
    /// so the next capture goes out full instead of as a doomed delta.
    fn heartbeat(&mut self, _session: &mut MobileSession) -> Result<HeartbeatOutcome> {
        Ok(HeartbeatOutcome::Unsupported)
    }

    /// Report a finished run's policy decision counters to the channel.
    /// The farm aggregates these across phones; other channels ignore
    /// them.
    fn record_policy(&mut self, _offloads: u64, _local: u64, _mispredictions: u64) {}

    /// Whether this channel negotiated the trace-context envelope
    /// (`CAP_TRACE_CTX`). Only then does the driver prepend a context to
    /// forward frames (and expect piggybacked clone events on replies).
    fn trace_capable(&self) -> bool {
        false
    }

    /// Whether this channel can carry scatter sub-job frames
    /// (`CAP_SCATTER`): N patched copies of one forward capture fanned
    /// to distinct clone slots in a single exchange.
    fn scatter_capable(&self) -> bool {
        false
    }

    /// Fan N sealed sub-job frames out and return their sealed
    /// sub-result frames (in whatever order the lanes finished — each
    /// sub-result carries its shard index) plus the exchange's byte
    /// totals. Any lane failure fails the whole exchange; the driver
    /// degrades to the single-clone offload of the same capture.
    fn scatter(&mut self, _frames: Vec<Vec<u8>>) -> Result<(Vec<Vec<u8>>, TransferBytes)> {
        Err(CloneCloudError::migration("channel cannot scatter"))
    }
}

impl<T: Transport> CloneChannel for NodeManager<T> {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        self.migrate(forward)
    }

    fn delta_capable(&self) -> bool {
        self.delta_negotiated()
    }

    fn disarm_delta(&mut self) {
        self.renegotiate_off();
    }

    fn codec(&self) -> Codec {
        self.negotiated_codec()
    }

    fn dict_capable(&self) -> bool {
        self.dict_negotiated()
    }

    fn heartbeat(&mut self, session: &mut MobileSession) -> Result<HeartbeatOutcome> {
        NodeManager::heartbeat(self, session)
    }

    fn trace_capable(&self) -> bool {
        self.trace_negotiated()
    }

    fn scatter_capable(&self) -> bool {
        self.scatter_negotiated()
    }

    fn scatter(&mut self, frames: Vec<Vec<u8>>) -> Result<(Vec<Vec<u8>>, TransferBytes)> {
        // One protocol, one link: sub-job frames cross the single
        // transport back-to-back and the peer (CloneServer or a farm
        // gateway) unwraps each in the shared execution core. A direct
        // single-slot peer serves the shards serially — correct, just
        // without the farm's lane parallelism.
        let mut replies = Vec::with_capacity(frames.len());
        let mut total = TransferBytes::default();
        for f in frames {
            let (r, t) = self.migrate(f)?;
            total.up += t.up;
            total.down += t.down;
            replies.push(r);
        }
        Ok((replies, total))
    }
}

/// In-process clone: the caller owns the clone process directly.
pub struct InlineClone {
    pub clone: Process,
    migrator: Migrator,
    session: CloneSession,
    codec: Codec,
    /// Run a slot garbage collection every this many roundtrips
    /// (0 = never) — same policy as the farm workers.
    pub gc_interval: u64,
    pub migrations: usize,
    /// Whether this channel "negotiated" the trace-context envelope,
    /// as a wire channel whose Hello carried `CAP_TRACE_CTX` would.
    trace: bool,
    /// Clone-side recorder. Stays disabled by default — a forward
    /// capsule carrying a context still gets its events recorded (and
    /// shipped back) via [`execute_migration`]'s ephemeral recorder.
    pub tracer: Tracer,
    /// Execution tier for offloaded spans (default tier 1; select the
    /// `interp` ablation with [`InlineClone::with_exec_tier`]). Profile
    /// state and the translation cache persist across roundtrips, like
    /// a farm slot's.
    pub tier: ExecTier,
    /// Clone-side serve counters accumulated across roundtrips (the
    /// tier counters land here too — `execute_migration` drains the
    /// engine per trip). The farm equivalent is `FarmStats`.
    pub serve_stats: CloneServeStats,
}

impl InlineClone {
    pub fn new(clone: Process, costs: CostParams) -> InlineClone {
        InlineClone {
            clone,
            migrator: Migrator::new(costs),
            session: CloneSession::new(false),
            codec: Codec::None,
            gc_interval: 8,
            migrations: 0,
            trace: false,
            tracer: Tracer::disabled(),
            tier: ExecTier::from_kind(ExecTierKind::default()),
            serve_stats: CloneServeStats::default(),
        }
    }

    /// Select the execution tier for offloaded spans on this clone.
    pub fn with_exec_tier(mut self, kind: ExecTierKind) -> InlineClone {
        self.tier = ExecTier::from_kind(kind);
        self
    }

    pub fn without_zygote_diff(mut self) -> InlineClone {
        self.migrator = self.migrator.without_zygote_diff();
        self
    }

    /// Enable delta capsules on this channel (pair with an enabled
    /// [`MobileSession`] in `run_distributed_session`).
    pub fn with_delta(mut self) -> InlineClone {
        self.session.set_enabled(true);
        self
    }

    /// Seal/open frames with the given codec, as a negotiated wire
    /// channel would (benches measure compression through this).
    pub fn with_codec(mut self, codec: Codec) -> InlineClone {
        self.codec = codec;
        self
    }

    /// Negotiate the session string dictionary on this channel, as a
    /// wire channel whose Hello carried `CAP_SESSION_DICT` would.
    pub fn with_dict(mut self) -> InlineClone {
        self.session.set_dict_enabled(true);
        self
    }

    /// Negotiate the trace-context envelope on this channel, as a wire
    /// channel whose Hello carried `CAP_TRACE_CTX` would: the driver may
    /// then prepend contexts and expect piggybacked clone events.
    pub fn with_trace(mut self) -> InlineClone {
        self.trace = true;
        self
    }

    /// Capture with the per-object baseline traversal instead of the
    /// page-epoch scan — the PR 4 shape, kept as the bench baseline.
    pub fn with_per_object_captures(mut self) -> InlineClone {
        self.session.set_paged(false);
        self
    }

    /// Re-send the full statics section in every delta — the PR 2 wire
    /// shape (bench ablation only).
    pub fn with_full_statics(mut self) -> InlineClone {
        self.session.ship_full_statics(true);
        self
    }

    /// Drop the clone-side baseline, as a recycled farm worker would:
    /// the next delta roundtrip is rejected with `NeedFull` and the
    /// session re-establishes from a full capture.
    pub fn evict_delta_baseline(&mut self) {
        self.session.evict();
    }
}

impl CloneChannel for InlineClone {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        let up = forward.len() as u64;
        let raw = open_frame(&forward)?;
        // Same execution core as the CloneServer and the farm workers —
        // including trace-context handling and dict-mode mirroring.
        let encoded = execute_migration(
            &self.migrator,
            &mut self.clone,
            &raw,
            u64::MAX,
            &mut self.serve_stats,
            &mut self.session,
            &mut self.tracer,
            &mut self.tier,
        )?;
        self.migrations += 1;
        if self.gc_interval > 0 && self.migrations as u64 % self.gc_interval == 0 {
            collect_slot_garbage(&mut self.clone, &self.session);
        }
        let bytes = seal_frame(self.codec, encoded);
        let down = bytes.len() as u64;
        Ok((bytes, TransferBytes { up, down }))
    }

    fn delta_capable(&self) -> bool {
        self.session.is_enabled()
    }

    fn disarm_delta(&mut self) {
        self.session.set_enabled(false);
    }

    fn codec(&self) -> Codec {
        self.codec
    }

    fn dict_capable(&self) -> bool {
        self.session.dict_enabled()
    }

    fn heartbeat(&mut self, session: &mut MobileSession) -> Result<HeartbeatOutcome> {
        if !self.session.is_enabled() {
            return Ok(HeartbeatOutcome::Unsupported);
        }
        crate::nodemanager::drive_heartbeat(session, |_epoch, digest, assignments| {
            self.session.check_heartbeat(&self.clone, digest, assignments)
        })
    }

    fn trace_capable(&self) -> bool {
        self.trace
    }

    fn scatter_capable(&self) -> bool {
        true
    }

    fn scatter(&mut self, frames: Vec<Vec<u8>>) -> Result<(Vec<Vec<u8>>, TransferBytes)> {
        // Each sub-job runs on a fresh fork of the clone process with
        // its own throwaway session, mirroring how the farm hands each
        // lane a distinct warm slot: shard state never bleeds between
        // lanes, and the channel's own delta session (lane 0) keeps its
        // baseline for the next monolithic trip.
        let mut replies = Vec::with_capacity(frames.len());
        let mut total = TransferBytes::default();
        for f in frames {
            total.up += f.len() as u64;
            let raw = open_frame(&f)?;
            let mut lane = self.clone.clone();
            let mut lane_session = CloneSession::new(true);
            let mut lane_tier = ExecTier::from_kind(ExecTierKind::default());
            let encoded = execute_migration(
                &self.migrator,
                &mut lane,
                &raw,
                u64::MAX,
                &mut self.serve_stats,
                &mut lane_session,
                &mut self.tracer,
                &mut lane_tier,
            )?;
            let bytes = seal_frame(self.codec, encoded);
            total.down += bytes.len() as u64;
            replies.push(bytes);
        }
        self.migrations += 1;
        Ok((replies, total))
    }
}

/// Outcome of a distributed run.
#[derive(Debug, Clone, Default)]
pub struct DistOutcome {
    pub virtual_ms: f64,
    pub result: Option<Value>,
    pub wall_s: f64,
    pub migrations: usize,
    /// Wire bytes moved (post-compression when a codec is negotiated).
    pub transfer: TransferBytes,
    /// Capsule bytes before frame compression, per direction. Equal to
    /// `transfer` on uncompressed channels; the quotient is the
    /// session's compression ratio.
    pub raw_up: u64,
    pub raw_down: u64,
    /// Aggregated phase timings (virtual ms).
    pub suspend_capture_ms: f64,
    pub uplink_ms: f64,
    pub downlink_ms: f64,
    pub merge_ms: f64,
    pub objects_shipped: usize,
    pub zygote_skipped: usize,
    /// Baseline objects referenced by id instead of shipped (delta).
    pub base_skipped: usize,
    /// Static slots serialized across all capsules.
    pub statics_shipped: usize,
    /// Roundtrips whose forward capsule was a delta.
    pub delta_roundtrips: usize,
    /// Roundtrips that went out as full captures.
    pub full_roundtrips: usize,
    /// Deltas rejected by the clone (`NeedFull`) and resent in full.
    pub delta_fallbacks: usize,
    /// Full capsules rejected over a session-dictionary digest mismatch
    /// (both replicas reset; the resend re-seeds).
    pub dict_fallbacks: usize,
    /// Capture work: objects examined across all captures, and (paged
    /// captures) pages opened / found dirty by the epoch scan.
    pub objects_scanned: usize,
    pub pages_scanned: usize,
    pub pages_dirty: usize,
    /// Session-dictionary savings this run: bytes the per-capsule table
    /// would have re-shipped, and entries newly learned.
    pub dict_hit_bytes: u64,
    pub dict_additions: u64,
    /// Baseline divergences a digest heartbeat caught *before* a doomed
    /// delta was built and shipped.
    pub heartbeat_preempts: usize,
    /// Virtual ms charged for digest-heartbeat roundtrips (the
    /// estimator's RTT samples).
    pub heartbeat_ms: f64,
    /// Policy decisions that migrated the span.
    pub offloads: usize,
    /// Policy decisions that ran the span locally (cost-model losses,
    /// forced-local runs, and degraded channel failures).
    pub local_fallbacks: usize,
    /// Decisions the after-the-fact scoring found wrong: decided local
    /// but the offload estimate beat the measured local time, or decided
    /// offload but the profiled local cost beat the measured offload
    /// time.
    pub mispredictions: usize,
    /// Channel failures absorbed by degrading the span to local
    /// execution instead of failing the run.
    pub channel_errors: usize,
    /// The most recent degraded channel error, surfaced for reports.
    pub last_channel_error: Option<String>,
    /// Offloads that committed via scatter/gather (each also counts in
    /// `offloads` and `migrations`).
    pub scatter_offloads: usize,
    /// Sub-jobs fanned out across all scatter attempts (committed or
    /// degraded).
    pub scatter_shards: usize,
    /// Gathers refused because two reverse capsules wrote the same
    /// object; each degraded to a single-clone offload of the same
    /// capture.
    pub scatter_conflicts: usize,
    /// Scatter attempts abandoned for any other reason (lane failure,
    /// malformed sub-result, non-delta reply); also degraded to the
    /// single-clone offload.
    pub scatter_failures: usize,
    /// Marginal offload decisions raced against a local fork.
    pub speculations: usize,
    /// Races the local fork won (the offload's merged state was
    /// discarded); each also counts as a misprediction.
    pub speculation_local_wins: usize,
    /// Races the clone won (the fork was discarded).
    pub speculation_clone_wins: usize,
}

/// Run the partitioned binary on `phone`, off-loading each migration
/// span through `channel` under the `net` cost model. Full captures every
/// roundtrip (the session-less baseline).
pub fn run_distributed<C: CloneChannel>(
    phone: &mut Process,
    channel: &mut C,
    net: &NetworkProfile,
    costs: &CostParams,
) -> Result<DistOutcome> {
    let mut session = MobileSession::disabled();
    run_distributed_session(phone, channel, net, costs, &mut session)
}

/// Session-aware distributed run: delta migration when `session` is
/// enabled AND the channel negotiated it. The session may be reused
/// across runs on the same phone/channel pairing to keep the baseline
/// cache warm. Every `CcStart` migrates (the seed's static policy) and
/// channel errors propagate; use [`run_distributed_policy`] for
/// per-invocation decisions.
pub fn run_distributed_session<C: CloneChannel>(
    phone: &mut Process,
    channel: &mut C,
    net: &NetworkProfile,
    costs: &CostParams,
    session: &mut MobileSession,
) -> Result<DistOutcome> {
    let mut engine = PolicyEngine::legacy_offload();
    run_distributed_policy(phone, channel, net, costs, session, &mut engine)
}

/// Policy-driven distributed run over a fixed network profile: the
/// engine answers migrate/local at every `CcStart`. The engine may be
/// reused across runs, keeping its link and capsule-size estimates warm
/// exactly like the session keeps its delta baseline.
pub fn run_distributed_policy<C: CloneChannel>(
    phone: &mut Process,
    channel: &mut C,
    net: &NetworkProfile,
    costs: &CostParams,
    session: &mut MobileSession,
    engine: &mut PolicyEngine,
) -> Result<DistOutcome> {
    let fixed = net.clone();
    run_distributed_with(phone, channel, |_trip| fixed.clone(), costs, session, engine)
}

/// The general driver: `net_at(trip)` supplies the link conditions in
/// effect at each migration-point encounter, so benches and traces can
/// sweep the network mid-run (a phone walking from WiFi through an EDGE
/// dead zone and back). The policy decision is made BEFORE any
/// suspend/capture work — a local decision pays zero capture cost.
pub fn run_distributed_with<C, N>(
    phone: &mut Process,
    channel: &mut C,
    net_at: N,
    costs: &CostParams,
    session: &mut MobileSession,
    engine: &mut PolicyEngine,
) -> Result<DistOutcome>
where
    C: CloneChannel,
    N: FnMut(usize) -> NetworkProfile,
{
    let mut off = Tracer::disabled();
    run_distributed_traced_with(phone, channel, net_at, costs, session, engine, &mut off)
}

/// [`run_distributed_policy`] with a flight recorder attached: every
/// phase of every trip lands in `tracer` as a span on the phone's
/// virtual timeline. When the channel negotiated `CAP_TRACE_CTX`, a
/// causality context rides ahead of each forward capsule and the
/// clone's own phase events come back piggybacked on the reverse
/// capsule, merged into the same timeline. Observe-only: results are
/// bit-identical with tracing on or off.
pub fn run_distributed_traced<C: CloneChannel>(
    phone: &mut Process,
    channel: &mut C,
    net: &NetworkProfile,
    costs: &CostParams,
    session: &mut MobileSession,
    engine: &mut PolicyEngine,
    tracer: &mut Tracer,
) -> Result<DistOutcome> {
    let fixed = net.clone();
    run_distributed_traced_with(
        phone,
        channel,
        move |_trip| fixed.clone(),
        costs,
        session,
        engine,
        tracer,
    )
}

/// A span decided local, awaiting its `CcStop`: scored after the fact
/// against the measured local time, then closed on the trace timeline.
struct LocalSpan {
    point: u32,
    /// Virtual clock at the decision (ms).
    start_ms: f64,
    /// The engine's offload estimate at the decision, if it had one.
    predicted: Option<f64>,
    trip: u32,
    /// Predicted per-term costs at decision time (0.0 = no estimate),
    /// carried forward for the post-hoc decision event.
    predicted_local_ms: f64,
    predicted_fwd_bytes: f64,
}

/// Predicted per-term costs from the engine's most recent decision
/// record. Unavailable estimates become 0.0, never NaN — decision
/// events may cross the wire and must stay equality-comparable.
fn predicted_terms(engine: &PolicyEngine) -> (f64, f64, f64) {
    match engine.log.last() {
        Some(r) => (
            r.local_ms.unwrap_or(0.0),
            r.offload_est_ms.unwrap_or(0.0),
            r.fwd_bytes_est.unwrap_or(0.0),
        ),
        None => (0.0, 0.0, 0.0),
    }
}

/// Build the forward trace context for one send, or `None` when the
/// channel did not negotiate `CAP_TRACE_CTX`. `parent_span` is the
/// tracer's current watermark — the clone's events causally follow it.
fn make_ctx(tracer: &Tracer, ctx_on: bool, trip: u32) -> Option<TraceCtx> {
    if !ctx_on {
        return None;
    }
    Some(TraceCtx {
        session_id: tracer.session_id(),
        trip,
        parent_span: tracer.mark() as u32,
        flags: if tracer.ship_clone_events() {
            FLAG_WANT_CLONE_EVENTS
        } else {
            0
        },
    })
}

/// [`run_distributed_with`] plus the flight recorder (see
/// [`run_distributed_traced`]). This is the real driver body; the
/// untraced entry points pass a disabled tracer, whose record calls
/// early-return on one branch.
pub fn run_distributed_traced_with<C, N>(
    phone: &mut Process,
    channel: &mut C,
    mut net_at: N,
    costs: &CostParams,
    session: &mut MobileSession,
    engine: &mut PolicyEngine,
    tracer: &mut Tracer,
) -> Result<DistOutcome>
where
    C: CloneChannel,
    N: FnMut(usize) -> NetworkProfile,
{
    let wall0 = std::time::Instant::now();
    if engine.forces_local() {
        // Forced-local ablation: nothing will ever be sent, so stand the
        // clone down up front — an armed channel must not retain delta
        // state (or emit reverse deltas) for a session that never syncs.
        session.disable();
    }
    if session.is_enabled() && !channel.delta_capable() {
        // The peer cannot carry deltas; degrade the session once, loudly
        // in the stats rather than silently per-roundtrip.
        session.disable();
    }
    if !session.is_enabled() {
        // Symmetric guard: an armed channel must not send back reverse
        // deltas this session cannot merge.
        channel.disarm_delta();
    }
    let migrator = Migrator::new(costs.clone());
    let codec = channel.codec();
    // Session dictionary: only a channel whose Hello negotiated
    // `CAP_SESSION_DICT` may carry the dictionary mode byte at all.
    let dict_on = channel.dict_capable();
    // Trace context rides only a channel whose Hello negotiated
    // `CAP_TRACE_CTX`; phone-side spans record whenever the tracer is
    // enabled, capable peer or not.
    let ctx_on = tracer.is_enabled() && channel.trace_capable();
    let dict0 = session.dict_stats();
    let entry = phone.program.entry()?;
    let tid = phone.spawn_thread(entry, &[])?;
    let mut out = DistOutcome::default();
    let mut trip = 0usize;
    let mut local_spans: Vec<LocalSpan> = Vec::new();

    let result = 'run: loop {
        match run_thread(phone, tid, &mut NoHooks, u64::MAX)? {
            RunExit::Completed(v) => break v,
            RunExit::ReintegrationPoint { point } => {
                // An offloaded span reintegrates at the clone; the phone
                // re-surfaces its CcStop only after the merge, when no
                // matching local span is pending — so a match here is
                // always a locally-run span completing.
                if local_spans.last().map(|s| s.point) == Some(point) {
                    let span = local_spans.pop().expect("matched above");
                    let actual_ms = phone.clock.now_ms() - span.start_ms;
                    let mispredicted = engine.score_local(actual_ms, span.predicted);
                    if mispredicted {
                        out.mispredictions += 1;
                    }
                    let t = phone.clock.now_us();
                    tracer.end(span.trip, Phase::LocalExec, t);
                    tracer.decision(
                        span.trip,
                        DecisionEvent {
                            offloaded: false,
                            predicted_local_ms: span.predicted_local_ms,
                            predicted_offload_ms: span.predicted.unwrap_or(0.0),
                            predicted_fwd_bytes: span.predicted_fwd_bytes as u64,
                            actual_ms,
                            mispredicted,
                        },
                        t,
                    );
                }
                continue;
            }
            RunExit::OutOfFuel => unreachable!("u64::MAX fuel"),
            RunExit::MigrationPoint { point } => {
                let net = net_at(trip);
                let trip32 = trip as u32;
                trip += 1;
                let t_decide = phone.clock.now_us();

                // --- policy: decide BEFORE suspend/capture, so a local
                // decision pays zero capture cost -----------------------
                if engine.decide(point, session.has_baseline()) == Decision::Local {
                    out.local_fallbacks += 1;
                    let (pred_local, _, pred_fwd) = predicted_terms(engine);
                    tracer.span(trip32, Phase::Decide, t_decide, t_decide);
                    tracer.begin(trip32, Phase::LocalExec, t_decide);
                    local_spans.push(LocalSpan {
                        point,
                        start_ms: phone.clock.now_ms(),
                        predicted: engine.last_offload_estimate(),
                        trip: trip32,
                        predicted_local_ms: pred_local,
                        predicted_fwd_bytes: pred_fwd,
                    });
                    continue;
                }
                out.offloads += 1;
                let (pred_local, pred_off, pred_fwd) = predicted_terms(engine);
                tracer.span(trip32, Phase::Decide, t_decide, t_decide);
                let span_start_ms = phone.clock.now_ms();

                // --- scatter/gather: a span the partition annotated as
                // data-parallel, on a channel that negotiated
                // `CAP_SCATTER`, fans ONE full capture across N clone
                // lanes and merges N disjoint reverse deltas ------------
                let scatter_width = match engine.span_shards(point) {
                    Some(w) if channel.scatter_capable() && session.is_enabled() => Some(w),
                    _ => None,
                };
                if scatter_width.is_some() {
                    // Every lane executes (and answers) against the same
                    // snapshot, so the fan-out wants a full capture —
                    // which also re-records the baseline the gather will
                    // validate against.
                    session.drop_baseline();
                }

                // --- speculation: a marginal decision races the local
                // interpreter on a fork of the phone against the offload;
                // the earlier virtual finisher commits, the loser is
                // dropped wholesale. Scattered spans never race — the fan
                // exists because local execution is the known loser.
                let mut spec_fork = if scatter_width.is_none() && engine.speculation_candidate()
                {
                    speculative_fork(phone, tid, point)
                } else {
                    None
                };

                // Long-idle baseline: probe with a digest heartbeat so a
                // diverged clone pre-arms `NeedFull` here, before a
                // doomed delta is built and shipped. The probe crosses
                // the real link: charge one small-frame roundtrip and
                // feed the estimator's RTT from it.
                if session.heartbeat_due() {
                    let outcome = match channel.heartbeat(session) {
                        Ok(o) => o,
                        // The probe found a dead channel before anything
                        // was captured: degrade this span to local, same
                        // contract as a failed roundtrip.
                        Err(e) if engine.degrades_to_local() && !e.is_need_full() => {
                            if let Some(fork) = spec_fork.take() {
                                // Dead channel mid-race: the local leg
                                // already ran on the fork, so commit it
                                // instead of re-running the span.
                                commit_racing_local(
                                    phone, fork.0, session, engine, &mut out, None, e,
                                    tracer, trip32,
                                );
                            } else {
                                degrade_to_local(
                                    phone,
                                    tid,
                                    session,
                                    engine,
                                    &mut out,
                                    &mut local_spans,
                                    point,
                                    trip32,
                                    None,
                                    e,
                                    tracer,
                                )?;
                            }
                            continue;
                        }
                        Err(e) => return Err(e),
                    };
                    if outcome != HeartbeatOutcome::Unsupported {
                        let rtt = net.transfer_ms(HEARTBEAT_PROBE_BYTES, true)
                            + net.transfer_ms(HEARTBEAT_ACK_BYTES, false);
                        let t_hb = phone.clock.now_us();
                        phone.clock.charge_ms(rtt);
                        out.heartbeat_ms += rtt;
                        engine.observe_rtt(rtt);
                        tracer.span(trip32, Phase::Heartbeat, t_hb, phone.clock.now_us());
                        tracer.instant(trip32, Mark::Heartbeat, phone.clock.now_us());
                    }
                    if outcome == HeartbeatOutcome::Divergent {
                        out.heartbeat_preempts += 1;
                        tracer.instant(
                            trip32,
                            Mark::HeartbeatDivergent,
                            phone.clock.now_us(),
                        );
                    }
                }

                let (capsule, phases) = migrator.migrate_out_capsule(phone, tid, session)?;
                absorb_capture_phases(&mut out, &phases);
                if tracer.is_enabled() {
                    // migrate_out charged the clock with suspend +
                    // capture: reconstruct both spans ending now.
                    let t = phone.clock.now_us();
                    let cap_us = phases.capture_ms * 1000.0;
                    let sus_us = phases.suspend_ms * 1000.0;
                    tracer.span(trip32, Phase::Suspend, t - cap_us - sus_us, t - cap_us);
                    tracer.span(trip32, Phase::Capture, t - cap_us, t);
                    tracer.counter(
                        trip32,
                        Counter::ObjectsShipped,
                        phases.objects_shipped as f64,
                        t,
                    );
                    tracer.counter(trip32, Counter::PagesDirty, phases.pages_dirty as f64, t);
                }
                let mut overhead_ms = phases.suspend_ms + phases.capture_ms;
                let first_was_delta = capsule.is_delta();
                if first_was_delta {
                    out.delta_roundtrips += 1;
                } else {
                    out.full_roundtrips += 1;
                }

                if let Some(width) = scatter_width {
                    if let Some(merge_ms) = try_scatter(
                        phone, channel, &net, &migrator, session, engine, &mut out, tracer,
                        &capsule, width, codec, dict_on, ctx_on, trip32, tid,
                    ) {
                        out.migrations += 1;
                        engine.observe_overhead(overhead_ms + merge_ms);
                        let actual_ms = phone.clock.now_ms() - span_start_ms;
                        let mispredicted = engine.score_offload(point, actual_ms);
                        if mispredicted {
                            out.mispredictions += 1;
                        }
                        tracer.decision(
                            trip32,
                            DecisionEvent {
                                offloaded: true,
                                predicted_local_ms: pred_local,
                                predicted_offload_ms: pred_off,
                                predicted_fwd_bytes: pred_fwd as u64,
                                actual_ms,
                                mispredicted,
                            },
                            phone.clock.now_us(),
                        );
                        continue;
                    }
                    // Conflict, lane failure, or a capsule that turned
                    // out not to follow the shard convention: the gather
                    // is validate-then-apply, so the phone and the
                    // baseline are exactly as the capture left them —
                    // fall through to the single-clone offload of the
                    // SAME capture.
                }

                let ctx = make_ctx(tracer, ctx_on, trip32);
                let (fwd, up_ms) = stamp_and_encode(
                    phone, &net, &mut out, capsule, codec, dict_on, session, tracer, trip32, ctx,
                )?;
                engine.observe_forward(fwd.len() as u64, up_ms, first_was_delta);

                // Roundtrip with a bounded NeedFull ladder. Rung 1: the
                // clone rejected the baseline (delta) or the dictionary
                // prefix (full) — reset the dictionary, recapture in
                // full, resend. Rung 2 (dict sessions only): resend the
                // same full capture on the self-describing inline table,
                // which cannot be rejected again.
                let mut fwd = fwd;
                let mut fwd_len = fwd.len() as u64;
                let mut sent_delta = first_was_delta;
                let mut needfull = 0u32;
                let (rbytes, transfer) = loop {
                    match channel.roundtrip(fwd) {
                        Ok(ok) => break ok,
                        Err(e) if e.is_need_full() && needfull < 2 => {
                            needfull += 1;
                            // The rejected frame still crossed the uplink.
                            out.transfer.up += fwd_len;
                            if sent_delta {
                                out.delta_fallbacks += 1;
                                out.delta_roundtrips -= 1;
                                out.full_roundtrips += 1;
                            } else {
                                // Only a dictionary digest mismatch can
                                // reject a full capsule; both replicas
                                // have reset.
                                out.dict_fallbacks += 1;
                            }
                            tracer.instant(trip32, Mark::NeedFull, phone.clock.now_us());
                            session.reset_dict();
                            tracer.instant(trip32, Mark::DictReset, phone.clock.now_us());
                            let (full, phases) =
                                migrator.recapture_full(phone, tid, session)?;
                            absorb_capture_phases(&mut out, &phases);
                            if tracer.is_enabled() {
                                let t = phone.clock.now_us();
                                tracer.span(
                                    trip32,
                                    Phase::Capture,
                                    t - phases.capture_ms * 1000.0,
                                    t,
                                );
                            }
                            overhead_ms += phases.capture_ms;
                            sent_delta = false;
                            let ctx = make_ctx(tracer, ctx_on, trip32);
                            let (f, up_ms) = if needfull >= 2 && dict_on {
                                stamp_and_encode_inline(
                                    phone, &net, &mut out, full, codec, session, tracer,
                                    trip32, ctx,
                                )?
                            } else {
                                stamp_and_encode(
                                    phone, &net, &mut out, full, codec, dict_on, session,
                                    tracer, trip32, ctx,
                                )?
                            };
                            engine.observe_forward(f.len() as u64, up_ms, false);
                            fwd_len = f.len() as u64;
                            fwd = f;
                        }
                        // A NeedFull that survives the whole ladder means
                        // the peer rejected even the self-describing
                        // inline resend — it is lying or broken, and the
                        // span degrades like any other channel error.
                        Err(e)
                            if engine.degrades_to_local()
                                && (!e.is_need_full() || needfull >= 2) =>
                        {
                            if let Some(fork) = spec_fork.take() {
                                commit_racing_local(
                                    phone,
                                    fork.0,
                                    session,
                                    engine,
                                    &mut out,
                                    Some((sent_delta, fwd_len)),
                                    e,
                                    tracer,
                                    trip32,
                                );
                            } else {
                                degrade_to_local(
                                    phone,
                                    tid,
                                    session,
                                    engine,
                                    &mut out,
                                    &mut local_spans,
                                    point,
                                    trip32,
                                    Some((sent_delta, fwd_len)),
                                    e,
                                    tracer,
                                )?;
                            }
                            continue 'run;
                        }
                        Err(e) => return Err(e),
                    }
                };
                out.transfer.up += transfer.up;
                out.transfer.down += transfer.down;
                out.migrations += 1;
                let t_sent = phone.clock.now_us();

                let decoded = open_frame(&rbytes).and_then(|raw| {
                    out.raw_down += raw.len() as u64;
                    // Piggybacked clone events (if any) sit ahead of the
                    // capsule; merge them into this timeline.
                    let (remote_events, craw) = trace::split_events(&raw)?;
                    tracer.absorb_remote(remote_events);
                    if dict_on {
                        Ok(Capsule::decode_with(craw, DictRead::Negotiated(session.dict()))?.0)
                    } else {
                        Capsule::decode(craw)
                    }
                });
                let rcapsule = match decoded {
                    Ok(c) => c,
                    // An undecodable reply is a hostile or corrupted
                    // peer, not a phone-side fault: the wire exchange
                    // completed but there is nothing to merge. Decoding
                    // is validate-then-apply (a rejected capsule leaves
                    // the phone and its dictionary replica untouched or
                    // cleanly reset), so the span can finish locally
                    // exactly like a dead link. No ladder applies — the
                    // reply cannot be re-requested — so a `NeedFull`
                    // verdict from the decoder degrades too.
                    Err(e) if engine.degrades_to_local() => {
                        // The bytes already crossed and were charged
                        // above; hand the degrade path a zero-byte
                        // attempt so only the roundtrip counters rewind.
                        out.migrations -= 1;
                        if let Some(fork) = spec_fork.take() {
                            commit_racing_local(
                                phone,
                                fork.0,
                                session,
                                engine,
                                &mut out,
                                Some((sent_delta, 0)),
                                e,
                                tracer,
                                trip32,
                            );
                        } else {
                            degrade_to_local(
                                phone,
                                tid,
                                session,
                                engine,
                                &mut out,
                                &mut local_spans,
                                point,
                                trip32,
                                Some((sent_delta, 0)),
                                e,
                                tracer,
                            )?;
                        }
                        continue 'run;
                    }
                    Err(e) => return Err(e),
                };
                // Adopt the clone's finish time, then pay the downlink
                // for the *wire* (sealed) bytes.
                phone.clock.advance_to_us(rcapsule.clock_us());
                tracer.span(trip32, Phase::CloneTrip, t_sent, phone.clock.now_us());
                let t_clone_done = phone.clock.now_us();
                let down_ms = net.transfer_ms(rbytes.len() as u64, false);
                phone.clock.charge_ms(down_ms);
                out.downlink_ms += down_ms;
                engine.observe_reverse(rbytes.len() as u64, down_ms);
                tracer.span(trip32, Phase::Downlink, t_clone_done, phone.clock.now_us());

                let merged = migrator.merge_back_capsule(phone, tid, &rcapsule, session);
                let (_stats, phases) = match merged {
                    Ok(v) => v,
                    // A `NeedFull` from the reply merge comes from the
                    // reverse-delta preconditions (missing or mismatched
                    // mobile baseline — a replayed capsule, a recycled
                    // worker), which fire before any process state is
                    // touched, so the span can still finish locally.
                    // Every other merge error may be mid-apply and stays
                    // fatal.
                    Err(e) if e.is_need_full() && engine.degrades_to_local() => {
                        out.migrations -= 1;
                        if let Some(fork) = spec_fork.take() {
                            commit_racing_local(
                                phone,
                                fork.0,
                                session,
                                engine,
                                &mut out,
                                Some((sent_delta, 0)),
                                e,
                                tracer,
                                trip32,
                            );
                        } else {
                            degrade_to_local(
                                phone,
                                tid,
                                session,
                                engine,
                                &mut out,
                                &mut local_spans,
                                point,
                                trip32,
                                Some((sent_delta, 0)),
                                e,
                                tracer,
                            )?;
                        }
                        continue 'run;
                    }
                    Err(e) => return Err(e),
                };
                out.merge_ms += phases.merge_ms;
                engine.observe_overhead(overhead_ms + phases.merge_ms);
                if tracer.is_enabled() {
                    let t_end = phone.clock.now_us();
                    tracer.span(trip32, Phase::Merge, t_end - phases.merge_ms * 1000.0, t_end);
                    tracer.counter(trip32, Counter::BytesUp, transfer.up as f64, t_end);
                    tracer.counter(trip32, Counter::BytesDown, transfer.down as f64, t_end);
                }
                let actual_ms = phone.clock.now_ms() - span_start_ms;
                if let Some((fork, local_done_ms)) = spec_fork.take() {
                    out.speculations += 1;
                    if local_done_ms < phone.clock.now_ms() {
                        // The local leg crossed its CcStop first: adopt
                        // the fork wholesale — heap, statics, clock —
                        // and discard the merged clone state atomically.
                        // The clone re-baselined for a merge that never
                        // committed, so the session resyncs from the
                        // next full capture. The race measured BOTH
                        // legs, so the loser's cost still feeds the
                        // estimator (the score_offload call below) and
                        // the decision is scored as a misprediction.
                        out.speculation_local_wins += 1;
                        out.mispredictions += 1;
                        engine.note_speculation(true);
                        engine.score_offload(point, actual_ms);
                        *phone = fork;
                        session.drop_baseline();
                        let t = phone.clock.now_us();
                        tracer.instant(trip32, Mark::Speculate, t);
                        tracer.decision(
                            trip32,
                            DecisionEvent {
                                offloaded: false,
                                predicted_local_ms: pred_local,
                                predicted_offload_ms: pred_off,
                                predicted_fwd_bytes: pred_fwd as u64,
                                actual_ms: local_done_ms - span_start_ms,
                                mispredicted: true,
                            },
                            t,
                        );
                        continue;
                    }
                    // Clone finished first: drop the fork, keep the
                    // merge that already landed.
                    out.speculation_clone_wins += 1;
                    engine.note_speculation(false);
                    tracer.instant(trip32, Mark::Speculate, phone.clock.now_us());
                }
                let mispredicted = engine.score_offload(point, actual_ms);
                if mispredicted {
                    out.mispredictions += 1;
                }
                tracer.decision(
                    trip32,
                    DecisionEvent {
                        offloaded: true,
                        predicted_local_ms: pred_local,
                        predicted_offload_ms: pred_off,
                        predicted_fwd_bytes: pred_fwd as u64,
                        actual_ms,
                        mispredicted,
                    },
                    phone.clock.now_us(),
                );
            }
        }
    };
    out.virtual_ms = phone.clock.now_ms();
    out.result = result;
    out.wall_s = wall0.elapsed().as_secs_f64();
    let dict1 = session.dict_stats();
    out.dict_hit_bytes = dict1.0.saturating_sub(dict0.0);
    out.dict_additions = dict1.1.saturating_sub(dict0.1);
    tracer.counter(
        0,
        Counter::DictHitBytes,
        out.dict_hit_bytes as f64,
        phone.clock.now_us(),
    );
    channel.record_policy(
        out.offloads as u64,
        out.local_fallbacks as u64,
        out.mispredictions as u64,
    );
    Ok(out)
}

/// The channel died mid-offload: resume the thread and run the span
/// locally, surfacing the error in the outcome instead of failing the
/// run. Any capture cost already paid stays charged; the baseline
/// recorded during capture describes state the clone never received, so
/// it is dropped (the next offload re-establishes from a full capture).
///
/// `attempt` is `Some((was_delta, wire_bytes))` when a forward frame was
/// built and sent: the roundtrip-flavor counter is rolled back (no
/// roundtrip completed) while the attempted bytes still land in
/// `transfer.up` — they were encoded and charged (`raw_up`/`uplink_ms`),
/// so the byte counters stay mutually consistent. `None` means the
/// failure happened at the heartbeat, before any capture (the thread
/// resume below is then a no-op).
#[allow(clippy::too_many_arguments)]
fn degrade_to_local(
    phone: &mut Process,
    tid: u32,
    session: &mut MobileSession,
    engine: &mut PolicyEngine,
    out: &mut DistOutcome,
    local_spans: &mut Vec<LocalSpan>,
    point: u32,
    trip: u32,
    attempt: Option<(bool, u64)>,
    e: CloneCloudError,
    tracer: &mut Tracer,
) -> Result<()> {
    phone.thread_mut(tid)?.status = ThreadStatus::Runnable;
    phone.resume_others(tid);
    session.drop_baseline();
    if let Some((was_delta, wire_bytes)) = attempt {
        if was_delta {
            out.delta_roundtrips -= 1;
        } else {
            out.full_roundtrips -= 1;
        }
        out.transfer.up += wire_bytes;
    }
    out.channel_errors += 1;
    out.last_channel_error = Some(e.to_string());
    out.offloads -= 1;
    out.local_fallbacks += 1;
    engine.note_degrade();
    tracer.instant(trip, Mark::Degrade, phone.clock.now_us());
    tracer.begin(trip, Phase::LocalExec, phone.clock.now_us());
    local_spans.push(LocalSpan {
        point,
        start_ms: phone.clock.now_ms(),
        predicted: None,
        trip,
        predicted_local_ms: 0.0,
        predicted_fwd_bytes: 0.0,
    });
    Ok(())
}

fn absorb_capture_phases(out: &mut DistOutcome, phases: &MigrationPhases) {
    out.suspend_capture_ms += phases.suspend_ms + phases.capture_ms;
    out.objects_shipped += phases.objects_shipped;
    out.zygote_skipped += phases.zygote_skipped;
    out.base_skipped += phases.base_skipped;
    out.statics_shipped += phases.statics_shipped;
    out.objects_scanned += phases.objects_scanned;
    out.pages_scanned += phases.pages_scanned;
    out.pages_dirty += phases.pages_dirty;
}

/// Run the race's local leg: fork the phone at the offload decision
/// (before any suspend/capture touched it) and interpret the span on the
/// fork through its matching `CcStop`. Returns the finished fork and its
/// virtual finish time, or `None` when the leg cannot adjudicate cleanly
/// (the span completed the whole program, or errored) — the offload then
/// proceeds unraced. The fork costs wall-clock only; its virtual clock
/// is the local leg's own timeline, independent of the offload charges
/// accruing on the real phone.
fn speculative_fork(phone: &Process, tid: u32, point: u32) -> Option<(Process, f64)> {
    let mut fork = phone.clone();
    loop {
        match run_thread(&mut fork, tid, &mut NoHooks, u64::MAX) {
            Ok(RunExit::ReintegrationPoint { point: p }) if p == point => {
                let done_ms = fork.clock.now_ms();
                return Some((fork, done_ms));
            }
            // Nested migration points inside the raced span run local on
            // this leg (their CcStarts are no-ops), and inner CcStops
            // just continue to the matching outer stop.
            Ok(RunExit::MigrationPoint { .. }) | Ok(RunExit::ReintegrationPoint { .. }) => {}
            _ => return None,
        }
    }
}

/// The channel died while a speculative race was in flight: the local
/// leg already ran to its `CcStop` on the fork, so instead of resuming
/// the suspended thread ([`degrade_to_local`]) the driver commits the
/// fork wholesale — same bookkeeping as a degrade (error surfaced,
/// offload rolled back to a local fallback) plus the race counters.
#[allow(clippy::too_many_arguments)]
fn commit_racing_local(
    phone: &mut Process,
    fork: Process,
    session: &mut MobileSession,
    engine: &mut PolicyEngine,
    out: &mut DistOutcome,
    attempt: Option<(bool, u64)>,
    e: CloneCloudError,
    tracer: &mut Tracer,
    trip: u32,
) {
    *phone = fork;
    // Any baseline recorded for the dead offload describes state the
    // clone never merged; the next offload re-establishes in full.
    session.drop_baseline();
    if let Some((was_delta, wire_bytes)) = attempt {
        if was_delta {
            out.delta_roundtrips -= 1;
        } else {
            out.full_roundtrips -= 1;
        }
        out.transfer.up += wire_bytes;
    }
    out.channel_errors += 1;
    out.last_channel_error = Some(e.to_string());
    out.offloads -= 1;
    out.local_fallbacks += 1;
    out.speculations += 1;
    out.speculation_local_wins += 1;
    engine.note_degrade();
    engine.note_speculation(true);
    tracer.instant(trip, Mark::Degrade, phone.clock.now_us());
    tracer.instant(trip, Mark::Speculate, phone.clock.now_us());
}

/// One scatter/gather attempt over an already-captured full capsule.
/// Shards the capsule by the `work(begin, end, shards)` convention, fans
/// the sub-job frames out through the channel, and gathers the reverse
/// deltas against the capture's baseline. Returns `Some(merge_ms)` when
/// the gather committed. `None` degrades to the single-clone offload of
/// the SAME capture: the gather is validate-then-apply, so every refusal
/// path (lane failure, malformed or missing sub-result, overlapping
/// write sets) leaves the phone process and the session baseline exactly
/// as `migrate_out_capsule` left them. Virtual-clock shape on commit:
/// serial uplink per frame, lanes overlap (the trip adopts the slowest
/// lane's finish), serial downlink for the gathered replies, then the
/// merge.
#[allow(clippy::too_many_arguments)]
fn try_scatter<C: CloneChannel>(
    phone: &mut Process,
    channel: &mut C,
    net: &NetworkProfile,
    migrator: &Migrator,
    session: &mut MobileSession,
    engine: &mut PolicyEngine,
    out: &mut DistOutcome,
    tracer: &mut Tracer,
    capsule: &Capsule,
    width: u16,
    codec: Codec,
    dict_on: bool,
    ctx_on: bool,
    trip: u32,
    tid: u32,
) -> Option<f64> {
    // A span annotated as data-parallel but whose live capture does not
    // follow the shard convention (delta capsule, missing registers,
    // empty range) silently runs monolithic — annotations are hints,
    // correctness never depends on them.
    let (begin, end, declared) = scatter_range(capsule)?;
    let shards = i64::from(width.min(declared));
    if shards < 2 {
        return None;
    }

    // --- fan-out: shard, encode, seal one sub-job frame per lane ------
    let total = end - begin;
    let mut frames = Vec::with_capacity(shards as usize);
    let mut sent_at = Vec::with_capacity(shards as usize);
    let mut up_bytes = 0u64;
    let mut fan_up_ms = 0.0;
    for i in 0..shards {
        // Contiguous near-equal sub-ranges covering [begin, end).
        let b = begin + total * i / shards;
        let e = begin + total * (i + 1) / shards;
        let sub = match shard_capsule(capsule, b, e) {
            Ok(s) => s,
            Err(_) => {
                out.scatter_failures += 1;
                return None;
            }
        };
        // Sub-jobs never ride the shared dictionary: N lanes decoding
        // shared-mode assignments would fork N diverging replicas of the
        // phone's one dictionary. The inline table is self-describing on
        // every lane.
        let raw = match if dict_on {
            sub.encode_with(DictMode::Inline)
        } else {
            sub.encode()
        } {
            Ok(r) => r,
            Err(_) => {
                out.scatter_failures += 1;
                return None;
            }
        };
        let ctx = make_ctx(tracer, ctx_on, trip);
        let (payload, ctx_len) = match &ctx {
            Some(c) => (trace::prepend_ctx(c, &raw), trace::TRACE_CTX_LEN),
            None => (raw, 0),
        };
        let framed = SubJobFrame {
            shard: i as u16,
            shards: shards as u16,
            payload,
        }
        .encode();
        out.raw_up += framed.len() as u64;
        // The sub-job header sits ahead of the (possibly ctx-prefixed)
        // capsule, so the patchable clock moves by the header's bytes.
        let head = SUB_JOB_PAYLOAD_OFFSET + ctx_len + CAPSULE_CLOCK_OFFSET;
        let mut wire = seal_frame_keep_head(codec, framed, head + 8);
        // Serial uplink on the single physical link: lane i resumes at
        // the instant its own frame finished arriving.
        let t0 = phone.clock.now_us();
        let up_ms = net.transfer_ms(wire.len() as u64, true);
        phone.clock.charge_ms(up_ms);
        out.uplink_ms += up_ms;
        fan_up_ms += up_ms;
        let clock = phone.clock.now_us().to_bits().to_be_bytes();
        patch_frame_payload(&mut wire, head, &clock)
            .expect("capsule header is always inside the preserved frame head");
        tracer.span(trip, Phase::Uplink, t0, phone.clock.now_us());
        sent_at.push(phone.clock.now_us());
        up_bytes += wire.len() as u64;
        frames.push(wire);
    }
    engine.observe_forward(up_bytes, fan_up_ms, false);
    out.scatter_shards += shards as usize;

    // --- exchange ------------------------------------------------------
    let (replies, transfer) = match channel.scatter(frames) {
        Ok(r) => r,
        Err(e) => {
            // The frames were encoded and charged; whatever crossed (or
            // died on) the uplink stays in the byte counters, same
            // contract as a degraded monolithic attempt.
            out.scatter_failures += 1;
            out.channel_errors += 1;
            out.last_channel_error = Some(e.to_string());
            out.transfer.up += up_bytes;
            return None;
        }
    };
    out.transfer.up += transfer.up;
    out.transfer.down += transfer.down;

    // --- decode: lanes answer in completion order; each sub-result
    // carries its shard index, so reorder into shard slots --------------
    let mut deltas: Vec<Option<DeltaPacket>> = Vec::new();
    deltas.resize_with(shards as usize, || None);
    let mut reply_wire_bytes = 0u64;
    for rbytes in &replies {
        reply_wire_bytes += rbytes.len() as u64;
        let decoded = (|| -> Result<()> {
            let raw = open_frame(rbytes)?;
            out.raw_down += raw.len() as u64;
            let (shard, payload) = decode_sub_result(&raw)?;
            let (remote_events, craw) = trace::split_events(&payload)?;
            tracer.absorb_remote(remote_events);
            let capsule = if dict_on {
                Capsule::decode_with(craw, DictRead::Negotiated(session.dict()))?.0
            } else {
                Capsule::decode(craw)?
            };
            let slot = deltas
                .get_mut(shard as usize)
                .ok_or_else(|| CloneCloudError::migration("sub-result shard out of range"))?;
            if slot.is_some() {
                return Err(CloneCloudError::migration("duplicate sub-result shard"));
            }
            match capsule {
                Capsule::Delta(d) => {
                    *slot = Some(d);
                    Ok(())
                }
                Capsule::Full(_) => Err(CloneCloudError::migration(
                    "scatter lane answered in full; the gather needs reverse deltas",
                )),
            }
        })();
        if let Err(e) = decoded {
            out.scatter_failures += 1;
            out.channel_errors += 1;
            out.last_channel_error = Some(e.to_string());
            return None;
        }
    }
    let deltas: Vec<DeltaPacket> = match deltas.into_iter().collect() {
        Some(d) => d,
        None => {
            out.scatter_failures += 1;
            out.channel_errors += 1;
            out.last_channel_error = Some("scatter gather is missing a shard".into());
            return None;
        }
    };

    // Lanes overlap in virtual time: each span runs from its frame's
    // arrival to that lane's own finish, and the phone waits for the
    // slowest before the gathered downlink starts.
    let mut max_clock = f64::MIN;
    for (i, d) in deltas.iter().enumerate() {
        tracer.span(trip, Phase::ScatterShard, sent_at[i], d.clock_us);
        max_clock = max_clock.max(d.clock_us);
    }
    phone.clock.advance_to_us(max_clock);
    let t_lanes_done = phone.clock.now_us();
    let down_ms = net.transfer_ms(reply_wire_bytes, false);
    phone.clock.charge_ms(down_ms);
    out.downlink_ms += down_ms;
    engine.observe_reverse(reply_wire_bytes, down_ms);
    tracer.span(trip, Phase::Downlink, t_lanes_done, phone.clock.now_us());

    // --- gather --------------------------------------------------------
    match migrator.gather_scatter_capsules(phone, tid, &deltas, session) {
        Ok((_stats, phases)) => {
            if tracer.is_enabled() {
                let t_end = phone.clock.now_us();
                tracer.span(trip, Phase::Gather, t_end - phases.merge_ms * 1000.0, t_end);
                tracer.counter(trip, Counter::BytesUp, transfer.up as f64, t_end);
                tracer.counter(trip, Counter::BytesDown, transfer.down as f64, t_end);
            }
            out.merge_ms += phases.merge_ms;
            out.scatter_offloads += 1;
            Some(phases.merge_ms)
        }
        Err(e) if e.is_scatter_conflict() => {
            // Two lanes wrote the same object. The merge validated
            // before applying anything, so nothing is half-merged —
            // count it, mark it, run the span on one clone instead.
            out.scatter_conflicts += 1;
            tracer.instant(trip, Mark::ScatterConflict, phone.clock.now_us());
            None
        }
        Err(e) => {
            out.scatter_failures += 1;
            out.last_channel_error = Some(e.to_string());
            None
        }
    }
}

/// Charge the uplink for the capsule's *wire* (sealed) bytes, then stamp
/// the post-transfer timestamp directly into the wire frame. Sealing
/// keeps the capsule header (through the clock field) out of the
/// compressed tail, so the clock is patched in place — one encode, one
/// compression pass, and the charged size IS the sent size. Returns the
/// frame plus the charged ms (the policy estimator's uplink sample).
///
/// `dict_on` says the channel negotiated `CAP_SESSION_DICT`: capsules
/// then carry the self-describing mode byte and are encoded against the
/// session's dictionary replica (or the inline per-capsule table when
/// the session keeps the dictionary disabled).
#[allow(clippy::too_many_arguments)]
fn stamp_and_encode(
    phone: &mut Process,
    net: &NetworkProfile,
    out: &mut DistOutcome,
    capsule: Capsule,
    codec: Codec,
    dict_on: bool,
    session: &mut MobileSession,
    tracer: &mut Tracer,
    trip: u32,
    ctx: Option<TraceCtx>,
) -> Result<(Vec<u8>, f64)> {
    let wall0 = tracer.is_enabled().then(std::time::Instant::now);
    // Session-lifetime encode scratch: the capsule streams into a buffer
    // whose capacity was learned on earlier trips, so a steady-state
    // trip makes one exact-size allocation (the split below) instead of
    // climbing a realloc ladder from empty every time.
    let mut w = WireWriter::from_vec(session.take_scratch());
    if !dict_on {
        capsule.encode_into_with(&mut w, DictMode::Off)?;
    } else if session.dict_enabled() {
        capsule.encode_into_with(&mut w, DictMode::Shared(session.dict()))?;
    } else {
        capsule.encode_into_with(&mut w, DictMode::Inline)?;
    }
    let mut store = w.into_vec();
    let raw = store.split_off(0);
    session.put_scratch(store);
    if let Some(w0) = wall0 {
        tracer.span_wall(
            trip,
            Phase::Encode,
            phone.clock.now_us(),
            w0.elapsed().as_micros() as u64,
        );
    }
    Ok(stamp_raw(phone, net, out, raw, codec, tracer, trip, ctx))
}

/// [`stamp_and_encode`] forced onto the inline per-capsule table — the
/// NeedFull ladder's last rung, which no dictionary state can reject.
#[allow(clippy::too_many_arguments)]
fn stamp_and_encode_inline(
    phone: &mut Process,
    net: &NetworkProfile,
    out: &mut DistOutcome,
    capsule: Capsule,
    codec: Codec,
    session: &mut MobileSession,
    tracer: &mut Tracer,
    trip: u32,
    ctx: Option<TraceCtx>,
) -> Result<(Vec<u8>, f64)> {
    let wall0 = tracer.is_enabled().then(std::time::Instant::now);
    let mut w = WireWriter::from_vec(session.take_scratch());
    capsule.encode_into_with(&mut w, DictMode::Inline)?;
    let mut store = w.into_vec();
    let raw = store.split_off(0);
    session.put_scratch(store);
    if let Some(w0) = wall0 {
        tracer.span_wall(
            trip,
            Phase::Encode,
            phone.clock.now_us(),
            w0.elapsed().as_micros() as u64,
        );
    }
    Ok(stamp_raw(phone, net, out, raw, codec, tracer, trip, ctx))
}

#[allow(clippy::too_many_arguments)]
fn stamp_raw(
    phone: &mut Process,
    net: &NetworkProfile,
    out: &mut DistOutcome,
    raw: Vec<u8>,
    codec: Codec,
    tracer: &mut Tracer,
    trip: u32,
    ctx: Option<TraceCtx>,
) -> (Vec<u8>, f64) {
    // The trace context rides *inside* the sealed frame, ahead of the
    // capsule; its bytes cross the link and are charged like any others.
    let (raw, ctx_len) = match &ctx {
        Some(c) => (trace::prepend_ctx(c, &raw), trace::TRACE_CTX_LEN),
        None => (raw, 0),
    };
    out.raw_up += raw.len() as u64;
    let mut wire = seal_frame_keep_head(codec, raw, ctx_len + CAPSULE_CLOCK_OFFSET + 8);
    let up_ms = net.transfer_ms(wire.len() as u64, true);
    phone.clock.charge_ms(up_ms);
    out.uplink_ms += up_ms;
    // Clone resumes at the post-transfer timestamp.
    let clock = phone.clock.now_us().to_bits().to_be_bytes();
    patch_frame_payload(&mut wire, ctx_len + CAPSULE_CLOCK_OFFSET, &clock)
        .expect("capsule header is always inside the preserved frame head");
    if tracer.is_enabled() {
        let t_sent = phone.clock.now_us();
        tracer.span(trip, Phase::Uplink, t_sent - up_ms * 1000.0, t_sent);
    }
    (wire, up_ms)
}

/// Assembly for the delta-migration workload used by
/// `benches/delta_migration.rs` and `examples/delta_offload.rs`:
/// `rounds` byte arrays of `payload` bytes hang off a static; each round
/// the phone dirties one byte of round `i`'s array, offloads a byte-sum
/// over it (the clone dirties a second byte and allocates a fresh
/// 4-byte array into `keep`), and accumulates the sum. Per round only
/// O(1) of the arrays changes — the shape delta migration exploits —
/// while a full capture re-ships all of them.
///
/// Requires `rounds <= 256` (byte-array stores) and `payload >= 2`.
pub fn delta_workload_src(rounds: i64, payload: i64) -> String {
    delta_statics_workload_src(rounds, payload, 0)
}

/// [`delta_workload_src`] plus `extra_statics` additional static slots
/// (`g0..gN`), each set once to a distinct int before the offload loop.
/// The statics never change afterwards, which is exactly the shape the
/// incremental-statics optimization exploits: the PR 2 delta format
/// re-serialized every one of them into every capsule, both directions.
pub fn delta_statics_workload_src(rounds: i64, payload: i64, extra_statics: usize) -> String {
    assert!((1..=256).contains(&rounds) && payload >= 2);
    let mut decls = String::new();
    let mut inits = String::new();
    for i in 0..extra_statics {
        decls.push_str(&format!("  static g{i}\n"));
        inits.push_str(&format!("    const r0 {i}\n    puts Delta.g{i} r0\n"));
    }
    format!(
        r#"
class Delta app
  static data
  static out
  static keep
{decls}  method main nargs=0 regs=12
{inits}    const r0 {rounds}
    newarr r1 val r0
    puts Delta.data r1
    const r2 0
    const r3 {payload}
  mk:
    ifge r2 r0 @mkd
    newarr r4 byte r3
    aput r1 r2 r4
    const r5 1
    add r2 r2 r5
    goto @mk
  mkd:
    const r6 0
    const r10 0
  loop:
    ifge r6 r0 @done
    aget r4 r1 r6
    const r5 0
    aput r4 r5 r6
    invoke r8 Delta.work r4
    add r10 r10 r8
    const r5 1
    add r6 r6 r5
    goto @loop
  done:
    puts Delta.out r10
    retv
  end
  method work nargs=1 regs=8
    ccstart 0
    len r1 r0
    const r2 0
    const r3 0
  sum:
    ifge r2 r1 @sd
    aget r4 r0 r2
    add r3 r3 r4
    const r5 1
    add r2 r2 r5
    goto @sum
  sd:
    const r6 1
    aput r0 r6 r3
    const r7 4
    newarr r2 byte r7
    const r6 0
    aput r2 r6 r3
    puts Delta.keep r2
    ccstop 0
    ret r3
  end
end
"#
    )
}

/// The `out` static `delta_workload_src` computes: round `i` sums array
/// `i`, which holds a single non-zero byte `i`, so out = Σ i.
pub fn delta_workload_expected(rounds: i64) -> i64 {
    rounds * (rounds - 1) / 2
}

fn scatter_workload_src_inner(slots: i64, payload: i64, spin: i64, conflict: bool) -> String {
    assert!(slots >= 2 && payload >= 1 && spin >= 0);
    // Every shard dirties slot 0 before touching its own range: any
    // scatter fan of width >= 2 then has two lanes writing one object
    // and the gather must refuse. Monolithically the cell is overwritten
    // by the i=0 pass, so the expected result does not change.
    let conflict_src = if conflict {
        "    const r6 0\n    aget r4 r3 r6\n    const r7 1\n    aput r4 r6 r7\n"
    } else {
        ""
    };
    format!(
        r#"
class Scat app
  static data
  static out
  method main nargs=0 regs=12
    const r0 {slots}
    newarr r1 val r0
    puts Scat.data r1
    const r6 {payload}
    const r2 0
  mk:
    ifge r2 r0 @mkd
    newarr r4 val r6
    aput r1 r2 r4
    const r5 1
    add r2 r2 r5
    goto @mk
  mkd:
    const r2 0
    invoke r7 Scat.work r2 r0 r0
    const r2 0
    const r8 0
  so:
    ifge r2 r0 @sod
    aget r4 r1 r2
    const r3 0
  si:
    ifge r3 r6 @sid
    aget r5 r4 r3
    add r8 r8 r5
    const r9 1
    add r3 r3 r9
    goto @si
  sid:
    const r9 1
    add r2 r2 r9
    goto @so
  sod:
    add r8 r8 r7
    puts Scat.out r8
    retv
  end
  method work nargs=3 regs=12
    ccstart 0
    gets r3 Scat.data
{conflict_src}    const r9 {spin}
    const r11 1
  outer:
    ifge r0 r1 @done
    aget r4 r3 r0
    len r5 r4
    const r6 0
  inner:
    ifge r6 r5 @id
    mul r7 r0 r6
    add r7 r7 r0
    const r10 0
  spin:
    ifge r10 r9 @spun
    add r10 r10 r11
    goto @spin
  spun:
    aput r4 r6 r7
    add r6 r6 r11
    goto @inner
  id:
    add r0 r0 r11
    goto @outer
  done:
    ccstop 0
    const r7 0
    ret r7
  end
end
"#
    )
}

/// Assembly for the scatter/gather workload: `slots` val-arrays of
/// `payload` cells hang off `Scat.data`; one `ccstart 0` span calls
/// `work(0, slots, slots)` — the rewriter's shard convention — which
/// fills slot `i`, cell `j` with `i*(j+1)` (plus `spin` busy iterations
/// per cell, so the span's compute can be scaled independently of its
/// state size); `main` then sums every cell into `Scat.out`. The span is
/// embarrassingly parallel over the slot range, so a partition may
/// annotate it with a scatter width.
pub fn scatter_workload_src(slots: i64, payload: i64, spin: i64) -> String {
    scatter_workload_src_inner(slots, payload, spin, false)
}

/// [`scatter_workload_src`] with a deliberate cross-shard collision:
/// the span also writes slot 0 before walking its own range, so any
/// scatter fan of width >= 2 dirties one object from two lanes and the
/// gather must refuse — degrade to a single clone, never corrupt. The
/// expected result is unchanged (the colliding cell is overwritten by
/// the `i = 0` pass).
pub fn scatter_conflict_workload_src(slots: i64, payload: i64, spin: i64) -> String {
    scatter_workload_src_inner(slots, payload, spin, true)
}

/// The `out` static the scatter workload computes:
/// Σ over slots and cells of `i*(j+1)`, and `work` returns 0.
pub fn scatter_workload_expected(slots: i64, payload: i64) -> i64 {
    (slots * (slots - 1) / 2) * (payload * (payload + 1) / 2)
}

/// Migration-phase record for the E3 bench: one round trip's breakdown.
#[derive(Debug, Clone, Default)]
pub struct RoundTripBreakdown {
    pub suspend_capture_ms: f64,
    pub uplink_ms: f64,
    pub clone_exec_ms: f64,
    pub downlink_ms: f64,
    pub merge_ms: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

impl DistOutcome {
    /// Total migration overhead (everything but local + clone compute).
    pub fn migration_overhead_ms(&self) -> f64 {
        self.suspend_capture_ms + self.uplink_ms + self.downlink_ms + self.merge_ms
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::appvm::assembler::assemble;
    use crate::appvm::natives::NodeEnv;
    use crate::appvm::zygote::build_template;
    use crate::appvm::{Heap, Program};
    use crate::device::{DeviceSpec, Location};
    use crate::vfs::SimFs;

    const ROUNDS: i64 = 10;
    const PAYLOAD: i64 = 256;
    const STATICS: usize = 24;

    fn setup() -> (Arc<Program>, Heap) {
        let program = Arc::new(
            assemble(&delta_statics_workload_src(ROUNDS, PAYLOAD, STATICS)).unwrap(),
        );
        crate::appvm::verifier::verify_program(&program).unwrap();
        let template = build_template(&program, 200, 11);
        (program, template)
    }

    fn make_proc(program: &Arc<Program>, template: &Heap, loc: Location) -> Process {
        let dev = match loc {
            Location::Mobile => DeviceSpec::phone_g1(),
            Location::Clone => DeviceSpec::clone_desktop(),
        };
        Process::fork_from_zygote(
            program.clone(),
            template,
            dev,
            loc,
            NodeEnv::with_rust_compute(SimFs::new()),
        )
    }

    fn run(
        program: &Arc<Program>,
        template: &Heap,
        delta: bool,
        full_statics: bool,
        codec: Codec,
    ) -> (DistOutcome, i64) {
        let mut phone = make_proc(program, template, Location::Mobile);
        let clone = make_proc(program, template, Location::Clone);
        let mut channel = InlineClone::new(clone, CostParams::default()).with_codec(codec);
        if delta {
            channel = channel.with_delta();
        }
        if full_statics {
            channel = channel.with_full_statics();
        }
        let mut session = MobileSession::new(delta);
        if full_statics {
            session.ship_full_statics(true);
        }
        let out = run_distributed_session(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
        )
        .unwrap();
        let main = program.entry().unwrap();
        let got = phone.statics[main.class.0 as usize][1].as_int().unwrap();
        (out, got)
    }

    /// Unchanged statics ride as baseline-implied on repeat deltas: the
    /// delta session serializes far fewer static slots than the legacy
    /// full-statics shape, at an identical result.
    #[test]
    fn delta_ships_only_dirty_statics() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);

        let (legacy, got_legacy) = run(&program, &template, true, true, Codec::None);
        let (incr, got_incr) = run(&program, &template, true, false, Codec::None);
        assert_eq!(got_legacy, expected);
        assert_eq!(got_incr, expected);
        assert_eq!(legacy.result, incr.result, "bit-identical results");

        // Legacy re-sends all non-null statics every forward capsule;
        // incremental sends them once (first contact) plus the O(1)
        // slots actually dirtied per round.
        assert!(
            legacy.statics_shipped > STATICS * (ROUNDS as usize - 1),
            "legacy shape re-ships statics ({} shipped)",
            legacy.statics_shipped
        );
        assert!(
            incr.statics_shipped < legacy.statics_shipped / 2,
            "incremental statics cut the section ({} vs {})",
            incr.statics_shipped,
            legacy.statics_shipped
        );
        assert!(
            incr.transfer.up < legacy.transfer.up,
            "fewer statics => fewer forward bytes"
        );
    }

    /// The negotiated codec shrinks the wire without touching results;
    /// raw counters expose the ratio.
    #[test]
    fn compressed_frames_shrink_the_wire() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);
        let (plain, got_plain) = run(&program, &template, true, false, Codec::None);
        let (lz, got_lz) = run(&program, &template, true, false, Codec::Lz);
        assert_eq!(got_plain, expected);
        assert_eq!(got_lz, expected);
        assert_eq!(plain.result, lz.result);
        assert_eq!(plain.raw_up, plain.transfer.up, "no codec: raw == wire");
        assert!(
            lz.transfer.up < lz.raw_up && lz.transfer.down < lz.raw_down,
            "sealed frames shrank: {} -> {} up, {} -> {} down",
            lz.raw_up,
            lz.transfer.up,
            lz.raw_down,
            lz.transfer.down
        );
        assert!(
            lz.transfer.up + lz.transfer.down < plain.transfer.up + plain.transfer.down,
            "compression reduced total wire bytes"
        );
    }

    /// A due heartbeat detects a diverged (evicted) clone baseline and
    /// pre-arms the full path: zero doomed deltas are built or shipped.
    #[test]
    fn heartbeat_preempts_doomed_delta() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);

        let mut phone = make_proc(&program, &template, Location::Mobile);
        let clone = make_proc(&program, &template, Location::Clone);
        let mut channel = InlineClone::new(clone, CostParams::default()).with_delta();
        let mut session = MobileSession::new(true);
        session.heartbeat_every(std::time::Duration::ZERO);

        let out = run_distributed_session(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
        )
        .unwrap();
        // Heartbeats before every roundtrip are all coherent mid-run.
        assert_eq!(out.heartbeat_preempts, 0);
        assert_eq!(out.delta_fallbacks, 0);
        assert_eq!(
            phone.statics[program.entry().unwrap().class.0 as usize][1].as_int(),
            Some(expected)
        );

        // Recycle the clone slot between runs (a farm would evict the
        // worker slot); the mobile still holds its baseline.
        channel.evict_delta_baseline();
        assert!(session.has_baseline());

        let mut phone2 = make_proc(&program, &template, Location::Mobile);
        let out2 = run_distributed_session(
            &mut phone2,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
        )
        .unwrap();
        assert!(out2.heartbeat_preempts >= 1, "divergence caught up front");
        assert_eq!(
            out2.delta_fallbacks, 0,
            "no doomed delta was shipped — the heartbeat pre-armed NeedFull"
        );
        assert_eq!(
            phone2.statics[program.entry().unwrap().class.0 as usize][1].as_int(),
            Some(expected)
        );
    }

    /// A channel that fails every roundtrip, as a dead TCP peer or a
    /// drained farm would.
    struct DeadChannel;

    impl CloneChannel for DeadChannel {
        fn roundtrip(&mut self, _forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
            Err(CloneCloudError::Transport("clone unreachable".into()))
        }
    }

    /// Forced-fallback matrix (1/2): `policy.force_local` with an armed
    /// delta session stands the clone down — no roundtrips, no reverse
    /// deltas, no baseline — and the run is pure local execution.
    #[test]
    fn force_local_stands_down_armed_delta_session() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);
        let mut phone = make_proc(&program, &template, Location::Mobile);
        let clone = make_proc(&program, &template, Location::Clone);
        let mut channel = InlineClone::new(clone, CostParams::default()).with_delta();
        assert!(channel.delta_capable(), "channel armed before the run");
        let mut session = MobileSession::new(true);
        let mut engine = crate::exec::PolicyEngine::force_local();

        let out = run_distributed_policy(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
            &mut engine,
        )
        .unwrap();

        assert_eq!(out.migrations, 0, "nothing crossed the wire");
        assert_eq!(out.offloads, 0);
        assert_eq!(out.local_fallbacks, ROUNDS as usize);
        assert_eq!(out.delta_roundtrips + out.full_roundtrips, 0);
        assert_eq!(out.transfer.up + out.transfer.down, 0);
        assert_eq!(
            out.suspend_capture_ms, 0.0,
            "a local decision pays zero capture cost"
        );
        assert!(
            !channel.delta_capable(),
            "the armed channel was disarmed: it cannot emit reverse deltas"
        );
        assert!(!session.is_enabled() && !session.has_baseline());
        assert_eq!(channel.migrations, 0);
        assert_eq!(
            phone.statics[program.entry().unwrap().class.0 as usize][1].as_int(),
            Some(expected),
            "pure local execution computes the same result"
        );
    }

    /// Forced-fallback matrix (2/2): `policy.force_offload` on a dead
    /// channel degrades every span to local execution with the error
    /// surfaced in the outcome — the run completes, no panic, no Err.
    #[test]
    fn force_offload_on_dead_channel_degrades_to_local() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);
        let mut phone = make_proc(&program, &template, Location::Mobile);
        let mut channel = DeadChannel;
        let mut session = MobileSession::disabled();
        let mut engine = crate::exec::PolicyEngine::force_offload();

        let out = run_distributed_policy(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
            &mut engine,
        )
        .unwrap();

        assert_eq!(out.channel_errors, ROUNDS as usize, "every span degraded");
        assert!(out
            .last_channel_error
            .as_deref()
            .unwrap()
            .contains("unreachable"));
        assert_eq!(out.migrations, 0);
        assert_eq!(out.offloads, 0, "degraded spans count as local");
        assert_eq!(out.local_fallbacks, ROUNDS as usize);
        assert_eq!(
            out.delta_roundtrips + out.full_roundtrips,
            0,
            "no roundtrip completed, flavor counters rolled back"
        );
        assert_eq!(
            out.transfer.up, out.raw_up,
            "attempted frames stay byte-consistent (no codec: wire == raw)"
        );
        assert!(out.transfer.up > 0 && out.transfer.down == 0);
        assert_eq!(
            phone.statics[program.entry().unwrap().class.0 as usize][1].as_int(),
            Some(expected),
            "results survive the dead channel"
        );

        // The legacy driver keeps the old contract: errors propagate.
        let mut phone2 = make_proc(&program, &template, Location::Mobile);
        let err = run_distributed(
            &mut phone2,
            &mut DeadChannel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
        );
        assert!(err.is_err(), "legacy path still fails fast");
    }

    /// Cost-model decisions end to end: the engine offloads on the first
    /// (cold) trip, measures a dead-slow link, and runs the remaining
    /// spans locally — scoring the cold offload as a misprediction.
    #[test]
    fn auto_engine_goes_local_on_measured_slow_link() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);

        // Price the span from a forced-local calibration run.
        let mut cal_phone = make_proc(&program, &template, Location::Mobile);
        let cal = run_distributed_policy(
            &mut cal_phone,
            &mut DeadChannel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut MobileSession::disabled(),
            &mut crate::exec::PolicyEngine::force_local(),
        )
        .unwrap();
        let local_ms = cal.virtual_ms / ROUNDS as f64;

        let awful = NetworkProfile {
            name: "awful".into(),
            latency_ms: 50_000.0,
            down_mbps: 0.01,
            up_mbps: 0.01,
        };
        let mut phone = make_proc(&program, &template, Location::Mobile);
        let clone = make_proc(&program, &template, Location::Clone);
        let mut channel = InlineClone::new(clone, CostParams::default());
        let mut engine = crate::exec::PolicyEngine::auto();
        engine.set_span(
            0,
            crate::exec::SpanCost {
                local_ms,
                clone_ms: local_ms / 21.0,
            },
        );
        let out = run_distributed_policy(
            &mut phone,
            &mut channel,
            &awful,
            &CostParams::default(),
            &mut MobileSession::disabled(),
            &mut engine,
        )
        .unwrap();

        assert!(out.offloads >= 1, "cold start offloads (static choice)");
        assert!(
            out.local_fallbacks > out.offloads,
            "measured link flips the rest local: {} local vs {} offload",
            out.local_fallbacks,
            out.offloads
        );
        assert!(out.mispredictions >= 1, "the cold offload scored as wrong");
        assert_eq!(
            phone.statics[program.entry().unwrap().class.0 as usize][1].as_int(),
            Some(expected),
            "mixed local/offload execution is bit-identical"
        );
    }

    /// The inline slot GC keeps tombstone threads bounded across many
    /// roundtrips without disturbing results or the delta baseline.
    #[test]
    fn slot_gc_bounds_inline_clone_growth() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);
        let mut phone = make_proc(&program, &template, Location::Mobile);
        let clone = make_proc(&program, &template, Location::Clone);
        let mut channel = InlineClone::new(clone, CostParams::default()).with_delta();
        channel.gc_interval = 4;
        let mut session = MobileSession::new(true);
        let out = run_distributed_session(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
        )
        .unwrap();
        assert_eq!(out.delta_fallbacks, 0, "GC never evicts the baseline");
        assert_eq!(
            phone.statics[program.entry().unwrap().class.0 as usize][1].as_int(),
            Some(expected)
        );
        assert!(
            channel.clone.threads.len() <= 4,
            "tombstone threads bounded by the GC interval, got {}",
            channel.clone.threads.len()
        );
    }

    /// The flight recorder: a traced delta session produces phone- AND
    /// clone-side spans on one merged timeline, phone-side spans cover
    /// >= 95% of each trip's virtual window, and execution results and
    /// counters are bit-identical to an untraced run.
    #[test]
    fn traced_run_merges_both_endpoints_and_changes_nothing() {
        use crate::trace::{phone_coverage, Endpoint, Event};

        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);
        let (plain, got_plain) = run(&program, &template, true, false, Codec::None);
        assert_eq!(got_plain, expected);

        let mut phone = make_proc(&program, &template, Location::Mobile);
        let clone = make_proc(&program, &template, Location::Clone);
        let mut channel = InlineClone::new(clone, CostParams::default())
            .with_delta()
            .with_trace();
        let mut session = MobileSession::new(true);
        let mut engine = PolicyEngine::legacy_offload();
        let mut tracer = Tracer::new(0x5E55, Endpoint::Phone, 8192);
        let out = run_distributed_traced(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
            &mut engine,
            &mut tracer,
        )
        .unwrap();

        // Observe-only: results and execution counters match untraced.
        assert_eq!(out.result, plain.result);
        assert_eq!(out.migrations, plain.migrations);
        assert_eq!(out.delta_roundtrips, plain.delta_roundtrips);
        assert_eq!(out.delta_fallbacks, plain.delta_fallbacks);
        assert_eq!(
            phone.statics[program.entry().unwrap().class.0 as usize][1].as_int(),
            Some(expected)
        );
        // The context + piggybacked events DO cross the (charged) wire.
        assert!(out.raw_up > plain.raw_up, "trace ctx bytes are accounted");

        let events: Vec<Event> = tracer.events().cloned().collect();
        assert!(
            events.iter().any(|e| e.endpoint == Endpoint::Clone),
            "clone events came home piggybacked"
        );
        let cov = phone_coverage(&events);
        assert!(cov >= 0.95, "phase spans cover the trips: {cov}");
        let rep = tracer.report();
        assert!(rep.phase(Endpoint::Clone, Phase::CloneExec).is_some());
        assert!(
            rep.phase(Endpoint::Phone, Phase::Uplink).unwrap().hist.count()
                >= ROUNDS as u64
        );
        assert_eq!(rep.decisions, ROUNDS as u64, "one decision event per trip");
    }

    /// A tracer on a channel that did NOT negotiate `CAP_TRACE_CTX`
    /// still records phone-side spans — but nothing trace-related rides
    /// the wire and no clone events appear.
    #[test]
    fn tracing_without_capability_stays_phone_local() {
        use crate::trace::Endpoint;

        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);
        let (plain, _) = run(&program, &template, true, false, Codec::None);

        let mut phone = make_proc(&program, &template, Location::Mobile);
        let clone = make_proc(&program, &template, Location::Clone);
        let mut channel = InlineClone::new(clone, CostParams::default()).with_delta();
        let mut session = MobileSession::new(true);
        let mut engine = PolicyEngine::legacy_offload();
        let mut tracer = Tracer::new(1, Endpoint::Phone, 8192);
        let out = run_distributed_traced(
            &mut phone,
            &mut channel,
            &NetworkProfile::wifi(),
            &CostParams::default(),
            &mut session,
            &mut engine,
            &mut tracer,
        )
        .unwrap();

        assert_eq!(out.raw_up, plain.raw_up, "no envelope bytes on the wire");
        assert_eq!(out.raw_down, plain.raw_down);
        assert!(
            tracer.events().all(|e| e.endpoint == Endpoint::Phone),
            "no clone events without the capability"
        );
        assert!(
            tracer.report().phase(Endpoint::Phone, Phase::Capture).is_some(),
            "phone-side spans still recorded"
        );
        assert_eq!(
            phone.statics[program.entry().unwrap().class.0 as usize][1].as_int(),
            Some(expected)
        );
    }

    // ---- scatter/gather + speculation ----------------------------------

    const SLOTS: i64 = 8;
    const CELLS: i64 = 256;
    const SPIN: i64 = 16;

    /// A link fast enough that exec dominates transfer — the regime the
    /// fan-out targets (wifi's 66 ms latency would charge N serial
    /// uplinks against a few ms of saved clone compute).
    fn lan() -> NetworkProfile {
        NetworkProfile {
            name: "lan".into(),
            latency_ms: 0.2,
            down_mbps: 400.0,
            up_mbps: 400.0,
        }
    }

    fn scatter_setup(conflict: bool) -> (Arc<Program>, Heap) {
        let src = if conflict {
            scatter_conflict_workload_src(SLOTS, CELLS, SPIN)
        } else {
            scatter_workload_src(SLOTS, CELLS, SPIN)
        };
        let program = Arc::new(assemble(&src).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let template = build_template(&program, 200, 11);
        (program, template)
    }

    /// One delta session over an inline clone, span 0 annotated with
    /// `width` scatter lanes (0 = monolithic).
    fn run_scatter(
        program: &Arc<Program>,
        template: &Heap,
        width: u16,
    ) -> (DistOutcome, i64) {
        let mut phone = make_proc(program, template, Location::Mobile);
        let clone = make_proc(program, template, Location::Clone);
        let mut channel = InlineClone::new(clone, CostParams::default()).with_delta();
        let mut session = MobileSession::new(true);
        let mut engine = crate::exec::PolicyEngine::force_offload();
        engine.set_span_shards(0, width);
        let out = run_distributed_policy(
            &mut phone,
            &mut channel,
            &lan(),
            &CostParams::default(),
            &mut session,
            &mut engine,
        )
        .unwrap();
        let main = program.entry().unwrap();
        let got = phone.statics[main.class.0 as usize][1].as_int().unwrap();
        (out, got)
    }

    /// The tentpole's speedup claim: fanning one capture across N lanes
    /// beats the single clone on virtual time — lanes overlap while the
    /// serial uplink and the gather stay charged — and the merged result
    /// is bit-identical at every width.
    #[test]
    fn scatter_beats_single_clone_bit_identically() {
        let (program, template) = scatter_setup(false);
        let expected = scatter_workload_expected(SLOTS, CELLS);

        let (single, got1) = run_scatter(&program, &template, 0);
        let (fan2, got2) = run_scatter(&program, &template, 2);
        let (fan4, got4) = run_scatter(&program, &template, 4);
        assert_eq!(got1, expected);
        assert_eq!(got2, expected);
        assert_eq!(got4, expected);
        assert_eq!(single.result, fan4.result, "bit-identical results");

        assert_eq!(single.scatter_offloads, 0);
        assert_eq!(single.scatter_shards, 0);
        assert_eq!(fan2.scatter_offloads, 1);
        assert_eq!(fan2.scatter_shards, 2);
        assert_eq!(fan4.scatter_offloads, 1);
        assert_eq!(fan4.scatter_shards, 4);
        assert_eq!(fan4.scatter_conflicts, 0);
        assert_eq!(fan4.scatter_failures, 0);
        assert_eq!(fan4.channel_errors, 0);
        assert_eq!(fan4.migrations, 1, "one scatter trip IS one migration");

        assert!(
            fan2.virtual_ms < single.virtual_ms,
            "2 lanes beat the single clone: {} vs {}",
            fan2.virtual_ms,
            single.virtual_ms
        );
        assert!(
            fan4.virtual_ms < fan2.virtual_ms,
            "4 lanes beat 2: {} vs {}",
            fan4.virtual_ms,
            fan2.virtual_ms
        );
    }

    /// Two lanes dirtying one object: the gather refuses (typed
    /// conflict), the driver retries the SAME capture on one clone, and
    /// the result is still bit-identical — degrade, never corrupt.
    #[test]
    fn scatter_conflict_degrades_to_one_clone() {
        let (program, template) = scatter_setup(true);
        let expected = scatter_workload_expected(SLOTS, CELLS);

        let (mono, got_m) = run_scatter(&program, &template, 0);
        let (fan, got_f) = run_scatter(&program, &template, 4);
        assert_eq!(got_m, expected);
        assert_eq!(got_f, expected, "conflicted fan still computes the truth");
        assert_eq!(mono.result, fan.result);

        assert_eq!(fan.scatter_shards, 4, "the fan-out was attempted");
        assert_eq!(fan.scatter_conflicts, 1, "the gather refused the overlap");
        assert_eq!(fan.scatter_offloads, 0, "no scatter committed");
        assert_eq!(fan.scatter_failures, 0);
        assert_eq!(fan.channel_errors, 0, "a conflict is not a link failure");
        assert_eq!(fan.migrations, 1, "the monolithic retry committed");
        assert_eq!(mono.scatter_conflicts, 0, "one clone cannot conflict");
    }

    /// Fault matrix over the scatter exchange: a 4-lane fan is 8 wire
    /// frames (4 sub-jobs, 4 sub-results). Kill the link at every frame
    /// boundary: any cut degrades the span — scatter refused, monolithic
    /// retry dead, local execution — with the error surfaced and the
    /// result bit-identical; an uncut exchange commits the gather.
    #[test]
    fn scatter_fault_matrix_degrades_cleanly() {
        let (program, template) = scatter_setup(false);
        let expected = scatter_workload_expected(SLOTS, CELLS);

        for kill in 0..=9u64 {
            let mut phone = make_proc(&program, &template, Location::Mobile);
            let clone = make_proc(&program, &template, Location::Clone);
            let inner = InlineClone::new(clone, CostParams::default()).with_delta();
            let mut channel = crate::exec::FaultInjectChannel::new(inner, kill);
            let mut session = MobileSession::new(true);
            let mut engine = crate::exec::PolicyEngine::force_offload();
            engine.set_span_shards(0, 4);
            let out = run_distributed_policy(
                &mut phone,
                &mut channel,
                &lan(),
                &CostParams::default(),
                &mut session,
                &mut engine,
            )
            .unwrap();
            let main = program.entry().unwrap();
            let got = phone.statics[main.class.0 as usize][1].as_int().unwrap();
            assert_eq!(got, expected, "kill_after={kill}: result survives the cut");
            if kill >= 8 {
                assert_eq!(out.scatter_offloads, 1, "kill_after={kill}");
                assert_eq!(out.channel_errors, 0, "kill_after={kill}");
                assert_eq!(out.migrations, 1, "kill_after={kill}");
            } else {
                assert_eq!(out.scatter_offloads, 0, "kill_after={kill}");
                assert!(out.scatter_failures >= 1, "kill_after={kill}");
                assert!(out.channel_errors >= 1, "kill_after={kill}");
                assert_eq!(out.migrations, 0, "kill_after={kill}");
                assert_eq!(out.offloads, 0, "kill_after={kill}");
                assert_eq!(out.local_fallbacks, 1, "kill_after={kill}");
            }
        }
    }

    /// Speculation pairing (1/3): marginal decisions race and the clone
    /// leg keeps winning on a fast link — every race commits the merged
    /// clone state, and the run is bit-identical to speculation off.
    #[test]
    fn speculation_commits_the_winning_clone_leg() {
        // A compute-heavy span: ~20 ms local vs ~1 ms on the clone, so
        // the offload leg wins every race on the lan profile.
        let program =
            Arc::new(assemble(&delta_statics_workload_src(ROUNDS, 2048, STATICS)).unwrap());
        crate::appvm::verifier::verify_program(&program).unwrap();
        let template = build_template(&program, 200, 11);
        let expected = delta_workload_expected(ROUNDS);

        let run_margin = |margin: f64| -> (DistOutcome, i64) {
            let mut phone = make_proc(&program, &template, Location::Mobile);
            let clone = make_proc(&program, &template, Location::Clone);
            let mut channel = InlineClone::new(clone, CostParams::default()).with_delta();
            let mut session = MobileSession::new(true);
            let mut engine =
                crate::exec::PolicyEngine::auto().with_speculation_margin(margin);
            engine.set_span(
                0,
                crate::exec::SpanCost {
                    local_ms: 50.0,
                    clone_ms: 1.0,
                },
            );
            let out = run_distributed_policy(
                &mut phone,
                &mut channel,
                &lan(),
                &CostParams::default(),
                &mut session,
                &mut engine,
            )
            .unwrap();
            let main = program.entry().unwrap();
            let got = phone.statics[main.class.0 as usize][1].as_int().unwrap();
            (out, got)
        };

        let (raced, got_r) = run_margin(1e12);
        let (plain, got_p) = run_margin(0.0);
        assert_eq!(got_r, expected);
        assert_eq!(got_p, expected);
        assert_eq!(raced.result, plain.result, "racing is invisible in results");
        assert_eq!(raced.migrations, plain.migrations);

        // Trip 0 is cold (no offload estimate — no race); the rest race.
        assert!(raced.speculations >= 1, "marginal trips raced");
        assert_eq!(raced.speculation_clone_wins, raced.speculations);
        assert_eq!(raced.speculation_local_wins, 0);
        assert_eq!(plain.speculations, 0, "margin 0 never races");
    }

    /// Speculation pairing (2/3): the link collapses mid-run while the
    /// estimator is still warm from better days — the stale-low estimate
    /// mispredicts Offload, the local leg finishes first, and the fork
    /// commits wholesale. Results stay bit-identical to speculation off.
    #[test]
    fn speculation_commits_the_winning_local_leg() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);
        let awful = NetworkProfile {
            name: "awful".into(),
            latency_ms: 20_000.0,
            down_mbps: 0.01,
            up_mbps: 0.01,
        };

        let run_sweep = |margin: f64| -> (DistOutcome, i64) {
            let mut phone = make_proc(&program, &template, Location::Mobile);
            let clone = make_proc(&program, &template, Location::Clone);
            let mut channel = InlineClone::new(clone, CostParams::default());
            let mut engine =
                crate::exec::PolicyEngine::auto().with_speculation_margin(margin);
            // Priced well above any lan-measured estimate, so the
            // decision stays Offload when the link turns awful.
            engine.set_span(
                0,
                crate::exec::SpanCost {
                    local_ms: 200.0,
                    clone_ms: 0.1,
                },
            );
            let fast = lan();
            let slow = awful.clone();
            let out = run_distributed_with(
                &mut phone,
                &mut channel,
                |trip| if trip < 2 { fast.clone() } else { slow.clone() },
                &CostParams::default(),
                &mut MobileSession::disabled(),
                &mut engine,
            )
            .unwrap();
            let main = program.entry().unwrap();
            let got = phone.statics[main.class.0 as usize][1].as_int().unwrap();
            (out, got)
        };

        let (raced, got_r) = run_sweep(1e12);
        let (plain, got_p) = run_sweep(0.0);
        assert_eq!(got_r, expected, "a committed fork is a correct phone");
        assert_eq!(got_p, expected);
        assert_eq!(raced.result, plain.result);

        assert!(
            raced.speculation_local_wins >= 1,
            "the awful trip's race went local: {} races, {} local wins",
            raced.speculations,
            raced.speculation_local_wins
        );
        assert!(raced.mispredictions >= 1, "the stale estimate was scored");
        assert_eq!(plain.speculations, 0);
    }

    /// Speculation pairing (3/3): the channel dies while a race is in
    /// flight. The local leg already ran on the fork, so the driver
    /// commits it instead of re-running the span — same error surfacing
    /// as a plain degrade, bit-identical results either way.
    #[test]
    fn speculation_survives_a_dead_channel() {
        let (program, template) = setup();
        let expected = delta_workload_expected(ROUNDS);

        let run_dead = |margin: f64| -> (DistOutcome, i64) {
            let mut phone = make_proc(&program, &template, Location::Mobile);
            let mut engine =
                crate::exec::PolicyEngine::auto().with_speculation_margin(margin);
            // Hand-fed estimator (the channel will never feed it): est
            // = 100 up + 0 clone + 20 down = 120 ms against a 130 ms
            // local price — marginal under a 50 ms margin, and Offload
            // still wins the decision.
            for _ in 0..2 {
                engine.observe_forward(10_000, 100.0, false);
                engine.observe_reverse(2_000, 20.0);
            }
            engine.set_span(
                0,
                crate::exec::SpanCost {
                    local_ms: 130.0,
                    clone_ms: 0.0,
                },
            );
            let out = run_distributed_policy(
                &mut phone,
                &mut DeadChannel,
                &NetworkProfile::wifi(),
                &CostParams::default(),
                &mut MobileSession::disabled(),
                &mut engine,
            )
            .unwrap();
            let main = program.entry().unwrap();
            let got = phone.statics[main.class.0 as usize][1].as_int().unwrap();
            (out, got)
        };

        let (raced, got_r) = run_dead(50.0);
        let (plain, got_p) = run_dead(0.0);
        assert_eq!(got_r, expected);
        assert_eq!(got_p, expected);
        assert_eq!(raced.result, plain.result);

        assert!(raced.speculations >= 1, "the fed estimator raced trip 0");
        assert_eq!(
            raced.speculation_local_wins, raced.speculations,
            "a dead channel always commits the local leg"
        );
        assert_eq!(raced.speculation_clone_wins, 0);
        assert_eq!(raced.migrations, 0);
        assert_eq!(raced.offloads, 0, "dead offloads rolled back to local");
        assert_eq!(raced.local_fallbacks, ROUNDS as usize);
        assert_eq!(raced.channel_errors, ROUNDS as usize, "every span surfaced");
        assert_eq!(plain.speculations, 0);
        assert_eq!(plain.channel_errors, ROUNDS as usize);
    }
}
