//! The CloneCloud distributed run (paper §4, Figure 7).
//!
//! The phone process executes the partitioned binary. At each `CcStart`
//! the policy engine (the partition DB already chose this binary, so the
//! answer is "migrate") suspends and captures the thread, charges the
//! uplink for the real capture bytes, and hands off to the clone channel.
//! The clone executes to `CcStop`, the reverse capture rides the
//! downlink, and the merge resumes the thread on the phone.
//!
//! Three clone channels: [`InlineClone`] (clone process owned by the
//! caller — deterministic, used by benches), any
//! `nodemanager::NodeManager` over a real transport (TCP loopback in the
//! examples), and [`FarmClone`] (a session on the multi-tenant clone
//! farm, `crate::farm` — N phones multiplexed over M workers). Virtual
//! time: the phone clock carries suspend + capture + uplink; the clone
//! continues from the received timestamp; the phone then adopts the
//! clone's finish time plus downlink + merge.
//!
//! **Delta migration**: [`run_distributed_session`] threads a
//! [`MobileSession`] through the run. After first contact, repeat
//! migrations ship only the mutated working set (epoch-based dirty
//! tracking, `migration::delta`); a clone that lost its baseline answers
//! `NeedFull` and the driver transparently falls back to a full capture.
//! The session can outlive a single run — keep it (and the channel)
//! around and repeat offloads from the same phone keep paying O(dirty)
//! instead of O(heap). [`run_distributed`] is the session-less wrapper:
//! full captures every time, the paper's original behavior.

use crate::appvm::interp::{run_thread, NoHooks, RunExit};
use crate::appvm::process::Process;
use crate::appvm::value::Value;
use crate::config::{CostParams, NetworkProfile};
use crate::error::{CloneCloudError, Result};
use crate::migration::{Capsule, CloneSession, MigrationPhases, Migrator, MobileSession};
use crate::nodemanager::{NodeManager, TransferBytes, Transport};

pub use crate::farm::FarmClone;

/// Where the offloaded span runs.
pub trait CloneChannel {
    /// Process one forward capsule; return the reverse capsule bytes (the
    /// clone's virtual finish time is inside the capsule). A typed
    /// `NeedFull` error asks the driver to resend a full capture.
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)>;

    /// Whether this channel negotiated delta capsules. The driver
    /// disables a session's delta path when the channel cannot carry it.
    fn delta_capable(&self) -> bool {
        false
    }

    /// Stand down the clone side's delta emission. The driver calls this
    /// when its `MobileSession` is disabled, so an armed channel cannot
    /// send back reverse deltas the mobile cannot merge.
    fn disarm_delta(&mut self) {}
}

impl<T: Transport> CloneChannel for NodeManager<T> {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        self.migrate(forward)
    }

    fn delta_capable(&self) -> bool {
        self.delta_negotiated()
    }

    fn disarm_delta(&mut self) {
        self.renegotiate_off();
    }
}

/// In-process clone: the caller owns the clone process directly.
pub struct InlineClone {
    pub clone: Process,
    migrator: Migrator,
    session: CloneSession,
    pub migrations: usize,
}

impl InlineClone {
    pub fn new(clone: Process, costs: CostParams) -> InlineClone {
        InlineClone {
            clone,
            migrator: Migrator::new(costs),
            session: CloneSession::new(false),
            migrations: 0,
        }
    }

    pub fn without_zygote_diff(mut self) -> InlineClone {
        self.migrator = self.migrator.without_zygote_diff();
        self
    }

    /// Enable delta capsules on this channel (pair with an enabled
    /// [`MobileSession`] in `run_distributed_session`).
    pub fn with_delta(mut self) -> InlineClone {
        self.session.set_enabled(true);
        self
    }

    /// Drop the clone-side baseline, as a recycled farm worker would:
    /// the next delta roundtrip is rejected with `NeedFull` and the
    /// session re-establishes from a full capture.
    pub fn evict_delta_baseline(&mut self) {
        self.session.evict();
    }
}

impl CloneChannel for InlineClone {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        let up = forward.len() as u64;
        let capsule = Capsule::decode(&forward)?;
        let (tid, _) = self
            .migrator
            .receive_capsule_at_clone(&mut self.clone, &capsule, &mut self.session)?;
        loop {
            match run_thread(&mut self.clone, tid, &mut NoHooks, u64::MAX)? {
                RunExit::ReintegrationPoint { .. } => break,
                RunExit::MigrationPoint { .. } => continue,
                RunExit::Completed(_) => {
                    return Err(CloneCloudError::migration(
                        "offloaded thread completed without reintegration",
                    ))
                }
                RunExit::OutOfFuel => unreachable!("u64::MAX fuel"),
            }
        }
        self.migrations += 1;
        let (rcapsule, _, _) = self.migrator.return_capsule_from_clone(
            &mut self.clone,
            tid,
            &mut self.session,
        )?;
        let bytes = rcapsule.encode();
        let down = bytes.len() as u64;
        Ok((bytes, TransferBytes { up, down }))
    }

    fn delta_capable(&self) -> bool {
        self.session.is_enabled()
    }

    fn disarm_delta(&mut self) {
        self.session.set_enabled(false);
    }
}

/// Outcome of a distributed run.
#[derive(Debug, Clone, Default)]
pub struct DistOutcome {
    pub virtual_ms: f64,
    pub result: Option<Value>,
    pub wall_s: f64,
    pub migrations: usize,
    pub transfer: TransferBytes,
    /// Aggregated phase timings (virtual ms).
    pub suspend_capture_ms: f64,
    pub uplink_ms: f64,
    pub downlink_ms: f64,
    pub merge_ms: f64,
    pub objects_shipped: usize,
    pub zygote_skipped: usize,
    /// Baseline objects referenced by id instead of shipped (delta).
    pub base_skipped: usize,
    /// Roundtrips whose forward capsule was a delta.
    pub delta_roundtrips: usize,
    /// Roundtrips that went out as full captures.
    pub full_roundtrips: usize,
    /// Deltas rejected by the clone (`NeedFull`) and resent in full.
    pub delta_fallbacks: usize,
}

/// Run the partitioned binary on `phone`, off-loading each migration
/// span through `channel` under the `net` cost model. Full captures every
/// roundtrip (the session-less baseline).
pub fn run_distributed<C: CloneChannel>(
    phone: &mut Process,
    channel: &mut C,
    net: &NetworkProfile,
    costs: &CostParams,
) -> Result<DistOutcome> {
    let mut session = MobileSession::disabled();
    run_distributed_session(phone, channel, net, costs, &mut session)
}

/// Session-aware distributed run: delta migration when `session` is
/// enabled AND the channel negotiated it. The session may be reused
/// across runs on the same phone/channel pairing to keep the baseline
/// cache warm.
pub fn run_distributed_session<C: CloneChannel>(
    phone: &mut Process,
    channel: &mut C,
    net: &NetworkProfile,
    costs: &CostParams,
    session: &mut MobileSession,
) -> Result<DistOutcome> {
    let wall0 = std::time::Instant::now();
    if session.is_enabled() && !channel.delta_capable() {
        // The peer cannot carry deltas; degrade the session once, loudly
        // in the stats rather than silently per-roundtrip.
        session.disable();
    }
    if !session.is_enabled() {
        // Symmetric guard: an armed channel must not send back reverse
        // deltas this session cannot merge.
        channel.disarm_delta();
    }
    let migrator = Migrator::new(costs.clone());
    let entry = phone.program.entry()?;
    let tid = phone.spawn_thread(entry, &[])?;
    let mut out = DistOutcome::default();

    let result = loop {
        match run_thread(phone, tid, &mut NoHooks, u64::MAX)? {
            RunExit::Completed(v) => break v,
            RunExit::ReintegrationPoint { .. } => continue, // local span
            RunExit::OutOfFuel => unreachable!("u64::MAX fuel"),
            RunExit::MigrationPoint { .. } => {
                // --- policy: this binary was picked for offload ---------
                let (capsule, phases) = migrator.migrate_out_capsule(phone, tid, session)?;
                absorb_capture_phases(&mut out, &phases);
                let sent_delta = capsule.is_delta();
                if sent_delta {
                    out.delta_roundtrips += 1;
                } else {
                    out.full_roundtrips += 1;
                }

                let fwd = stamp_and_encode(phone, net, &mut out, capsule);
                let fwd_len = fwd.len() as u64;
                let (rbytes, transfer) = match channel.roundtrip(fwd) {
                    Ok(ok) => ok,
                    Err(e) if e.is_need_full() && sent_delta => {
                        // The rejected delta still crossed the uplink.
                        out.transfer.up += fwd_len;
                        // The clone lost/rejected the baseline: resend in
                        // full.
                        out.delta_fallbacks += 1;
                        out.delta_roundtrips -= 1;
                        out.full_roundtrips += 1;
                        let (full, phases) = migrator.recapture_full(phone, tid, session)?;
                        absorb_capture_phases(&mut out, &phases);
                        let fwd = stamp_and_encode(phone, net, &mut out, full);
                        channel.roundtrip(fwd)?
                    }
                    Err(e) => return Err(e),
                };
                out.transfer.up += transfer.up;
                out.transfer.down += transfer.down;
                out.migrations += 1;

                let rcapsule = Capsule::decode(&rbytes)?;
                // Adopt the clone's finish time, then pay the downlink.
                phone.clock.advance_to_us(rcapsule.clock_us());
                let down_ms = net.transfer_ms(rbytes.len() as u64, false);
                phone.clock.charge_ms(down_ms);
                out.downlink_ms += down_ms;

                let (_stats, phases) =
                    migrator.merge_back_capsule(phone, tid, &rcapsule, session)?;
                out.merge_ms += phases.merge_ms;
            }
        }
    };
    out.virtual_ms = phone.clock.now_ms();
    out.result = result;
    out.wall_s = wall0.elapsed().as_secs_f64();
    Ok(out)
}

fn absorb_capture_phases(out: &mut DistOutcome, phases: &MigrationPhases) {
    out.suspend_capture_ms += phases.suspend_ms + phases.capture_ms;
    out.objects_shipped += phases.objects_shipped;
    out.zygote_skipped += phases.zygote_skipped;
    out.base_skipped += phases.base_skipped;
}

/// Charge the uplink for the capsule's real bytes, stamp the post-transfer
/// timestamp into it, and encode the final wire form.
fn stamp_and_encode(
    phone: &mut Process,
    net: &NetworkProfile,
    out: &mut DistOutcome,
    mut capsule: Capsule,
) -> Vec<u8> {
    let bytes = capsule.encode();
    let up_ms = net.transfer_ms(bytes.len() as u64, true);
    phone.clock.charge_ms(up_ms);
    out.uplink_ms += up_ms;
    // Clone resumes at the post-transfer timestamp.
    capsule.set_clock_us(phone.clock.now_us());
    capsule.encode()
}

/// Assembly for the delta-migration workload used by
/// `benches/delta_migration.rs` and `examples/delta_offload.rs`:
/// `rounds` byte arrays of `payload` bytes hang off a static; each round
/// the phone dirties one byte of round `i`'s array, offloads a byte-sum
/// over it (the clone dirties a second byte and allocates a fresh
/// 4-byte array into `keep`), and accumulates the sum. Per round only
/// O(1) of the arrays changes — the shape delta migration exploits —
/// while a full capture re-ships all of them.
///
/// Requires `rounds <= 256` (byte-array stores) and `payload >= 2`.
pub fn delta_workload_src(rounds: i64, payload: i64) -> String {
    assert!((1..=256).contains(&rounds) && payload >= 2);
    format!(
        r#"
class Delta app
  static data
  static out
  static keep
  method main nargs=0 regs=12
    const r0 {rounds}
    newarr r1 val r0
    puts Delta.data r1
    const r2 0
    const r3 {payload}
  mk:
    ifge r2 r0 @mkd
    newarr r4 byte r3
    aput r1 r2 r4
    const r5 1
    add r2 r2 r5
    goto @mk
  mkd:
    const r6 0
    const r10 0
  loop:
    ifge r6 r0 @done
    aget r4 r1 r6
    const r5 0
    aput r4 r5 r6
    invoke r8 Delta.work r4
    add r10 r10 r8
    const r5 1
    add r6 r6 r5
    goto @loop
  done:
    puts Delta.out r10
    retv
  end
  method work nargs=1 regs=8
    ccstart 0
    len r1 r0
    const r2 0
    const r3 0
  sum:
    ifge r2 r1 @sd
    aget r4 r0 r2
    add r3 r3 r4
    const r5 1
    add r2 r2 r5
    goto @sum
  sd:
    const r6 1
    aput r0 r6 r3
    const r7 4
    newarr r2 byte r7
    const r6 0
    aput r2 r6 r3
    puts Delta.keep r2
    ccstop 0
    ret r3
  end
end
"#
    )
}

/// The `out` static `delta_workload_src` computes: round `i` sums array
/// `i`, which holds a single non-zero byte `i`, so out = Σ i.
pub fn delta_workload_expected(rounds: i64) -> i64 {
    rounds * (rounds - 1) / 2
}

/// Migration-phase record for the E3 bench: one round trip's breakdown.
#[derive(Debug, Clone, Default)]
pub struct RoundTripBreakdown {
    pub suspend_capture_ms: f64,
    pub uplink_ms: f64,
    pub clone_exec_ms: f64,
    pub downlink_ms: f64,
    pub merge_ms: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

impl DistOutcome {
    /// Total migration overhead (everything but local + clone compute).
    pub fn migration_overhead_ms(&self) -> f64 {
        self.suspend_capture_ms + self.uplink_ms + self.downlink_ms + self.merge_ms
    }
}
