//! The CloneCloud distributed run (paper §4, Figure 7).
//!
//! The phone process executes the partitioned binary. At each `CcStart`
//! the policy engine (the partition DB already chose this binary, so the
//! answer is "migrate") suspends and captures the thread, charges the
//! uplink for the real capture bytes, and hands off to the clone channel.
//! The clone executes to `CcStop`, the reverse capture rides the
//! downlink, and the merge resumes the thread on the phone.
//!
//! Three clone channels: [`InlineClone`] (clone process owned by the
//! caller — deterministic, used by benches), any
//! `nodemanager::NodeManager` over a real transport (TCP loopback in the
//! examples), and [`FarmClone`] (a session on the multi-tenant clone
//! farm, `crate::farm` — N phones multiplexed over M workers). Virtual
//! time: the phone clock carries suspend + capture + uplink; the clone
//! continues from the received timestamp; the phone then adopts the
//! clone's finish time plus downlink + merge.

use crate::appvm::interp::{run_thread, NoHooks, RunExit};
use crate::appvm::process::Process;
use crate::appvm::value::Value;
use crate::config::{CostParams, NetworkProfile};
use crate::error::{CloneCloudError, Result};
use crate::migration::{CapturePacket, MigrationPhases, Migrator};
use crate::nodemanager::{NodeManager, TransferBytes, Transport};

pub use crate::farm::FarmClone;

/// Where the offloaded span runs.
pub trait CloneChannel {
    /// Process one forward capture; return the reverse capture bytes and
    /// the clone's virtual finish time is inside the packet.
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)>;
}

impl<T: Transport> CloneChannel for NodeManager<T> {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        self.migrate(forward)
    }
}

/// In-process clone: the caller owns the clone process directly.
pub struct InlineClone {
    pub clone: Process,
    migrator: Migrator,
    pub migrations: usize,
}

impl InlineClone {
    pub fn new(clone: Process, costs: CostParams) -> InlineClone {
        InlineClone {
            clone,
            migrator: Migrator::new(costs),
            migrations: 0,
        }
    }

    pub fn without_zygote_diff(mut self) -> InlineClone {
        self.migrator = self.migrator.without_zygote_diff();
        self
    }
}

impl CloneChannel for InlineClone {
    fn roundtrip(&mut self, forward: Vec<u8>) -> Result<(Vec<u8>, TransferBytes)> {
        let up = forward.len() as u64;
        let packet = CapturePacket::decode(&forward)?;
        let (tid, table, _) = self.migrator.receive_at_clone(&mut self.clone, &packet)?;
        loop {
            match run_thread(&mut self.clone, tid, &mut NoHooks, u64::MAX)? {
                RunExit::ReintegrationPoint { .. } => break,
                RunExit::MigrationPoint { .. } => continue,
                RunExit::Completed(_) => {
                    return Err(CloneCloudError::migration(
                        "offloaded thread completed without reintegration",
                    ))
                }
                RunExit::OutOfFuel => unreachable!("u64::MAX fuel"),
            }
        }
        self.migrations += 1;
        let (rpacket, _, _) = self
            .migrator
            .return_from_clone(&mut self.clone, tid, table)?;
        let bytes = rpacket.encode();
        let down = bytes.len() as u64;
        Ok((bytes, TransferBytes { up, down }))
    }
}

/// Outcome of a distributed run.
#[derive(Debug, Clone, Default)]
pub struct DistOutcome {
    pub virtual_ms: f64,
    pub result: Option<Value>,
    pub wall_s: f64,
    pub migrations: usize,
    pub transfer: TransferBytes,
    /// Aggregated phase timings (virtual ms).
    pub suspend_capture_ms: f64,
    pub uplink_ms: f64,
    pub downlink_ms: f64,
    pub merge_ms: f64,
    pub objects_shipped: usize,
    pub zygote_skipped: usize,
}

/// Run the partitioned binary on `phone`, off-loading each migration
/// span through `channel` under the `net` cost model.
pub fn run_distributed<C: CloneChannel>(
    phone: &mut Process,
    channel: &mut C,
    net: &NetworkProfile,
    costs: &CostParams,
) -> Result<DistOutcome> {
    let wall0 = std::time::Instant::now();
    let migrator = Migrator::new(costs.clone());
    let entry = phone.program.entry()?;
    let tid = phone.spawn_thread(entry, &[])?;
    let mut out = DistOutcome::default();

    let result = loop {
        match run_thread(phone, tid, &mut NoHooks, u64::MAX)? {
            RunExit::Completed(v) => break v,
            RunExit::ReintegrationPoint { .. } => continue, // local span
            RunExit::OutOfFuel => unreachable!("u64::MAX fuel"),
            RunExit::MigrationPoint { .. } => {
                // --- policy: this binary was picked for offload ---------
                let (mut packet, phases) = migrator.migrate_out(phone, tid)?;
                out.suspend_capture_ms += phases.suspend_ms + phases.capture_ms;
                out.objects_shipped += phases.objects_shipped;
                out.zygote_skipped += phases.zygote_skipped;

                // Uplink on the phone's slow path, for the real bytes.
                let fwd = {
                    let bytes = packet.encode();
                    let up_ms = net.transfer_ms(bytes.len() as u64, true);
                    phone.clock.charge_ms(up_ms);
                    out.uplink_ms += up_ms;
                    // Clone resumes at the post-transfer timestamp.
                    packet.clock_us = phone.clock.now_us();
                    packet.encode()
                };

                let (rbytes, transfer) = channel.roundtrip(fwd)?;
                out.transfer.up += transfer.up;
                out.transfer.down += transfer.down;
                out.migrations += 1;

                let rpacket = CapturePacket::decode(&rbytes)?;
                // Adopt the clone's finish time, then pay the downlink.
                phone.clock.advance_to_us(rpacket.clock_us);
                let down_ms = net.transfer_ms(rbytes.len() as u64, false);
                phone.clock.charge_ms(down_ms);
                out.downlink_ms += down_ms;

                let (_stats, phases) = migrator.merge_back(phone, tid, &rpacket)?;
                out.merge_ms += phases.merge_ms;
            }
        }
    };
    out.virtual_ms = phone.clock.now_ms();
    out.result = result;
    out.wall_s = wall0.elapsed().as_secs_f64();
    Ok(out)
}

/// Migration-phase record for the E3 bench: one round trip's breakdown.
#[derive(Debug, Clone, Default)]
pub struct RoundTripBreakdown {
    pub suspend_capture_ms: f64,
    pub uplink_ms: f64,
    pub clone_exec_ms: f64,
    pub downlink_ms: f64,
    pub merge_ms: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

impl DistOutcome {
    /// Total migration overhead (everything but local + clone compute).
    pub fn migration_overhead_ms(&self) -> f64 {
        self.suspend_capture_ms + self.uplink_ms + self.downlink_ms + self.merge_ms
    }
}

#[allow(unused)]
fn _assert_phases_used(p: MigrationPhases) -> f64 {
    p.suspend_ms
}
