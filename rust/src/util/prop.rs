//! Property-test harness substrate (the environment has no proptest crate).
//!
//! A minimal quickcheck-style loop: generate `cases` random inputs from a
//! seeded [`Rng`], run the property, and on failure report the seed and
//! case index so the exact failing input can be replayed deterministically.
//! Used by the ILP-vs-exhaustive, mapping-table, and capture/merge
//! round-trip property tests.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub seed: u64,
    pub cases: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            seed: 0xC10E_C10D,
            cases: 100,
        }
    }
}

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `prop` against `cases` generated inputs. `gen` receives a fresh,
/// per-case deterministic RNG. Panics with seed + case index on failure.
pub fn forall<T, G, P>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> CaseResult,
    T: std::fmt::Debug,
{
    for case in 0..cfg.cases {
        // Derive a distinct, reproducible stream per case.
        let mut rng = Rng::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={:#x}, case={case}): {msg}\ninput: {input:?}",
                cfg.seed
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_eq<A: PartialEq + std::fmt::Debug>(a: A, b: A, ctx: &str) -> CaseResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

/// Approximate float equality for cost comparisons.
pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> CaseResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            PropConfig { seed: 1, cases: 50 },
            |rng| rng.range_i64(0, 100),
            |&x| ensure(x >= 0 && x <= 100, "in range"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(
            PropConfig { seed: 2, cases: 50 },
            |rng| rng.range_i64(0, 100),
            |&x| ensure(x < 90, "x too big"),
        );
    }

    #[test]
    fn generation_is_deterministic() {
        let mut a_vals = Vec::new();
        forall(
            PropConfig { seed: 3, cases: 10 },
            |rng| rng.next_u64(),
            |&x| {
                a_vals.push(x);
                Ok(())
            },
        );
        let mut b_vals = Vec::new();
        forall(
            PropConfig { seed: 3, cases: 10 },
            |rng| rng.next_u64(),
            |&x| {
                b_vals.push(x);
                Ok(())
            },
        );
        assert_eq!(a_vals, b_vals);
    }

    #[test]
    fn ensure_close_tolerates() {
        assert!(ensure_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-9, "x").is_err());
    }
}
