//! Small statistics helpers shared by the bench harness and metrics.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; returns an all-NaN summary for empty input.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / if n > 1 { (n - 1) as f64 } else { 1.0 };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
        }
    }
}

/// Percentile over a pre-sorted slice (nearest-rank with interpolation).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sub-buckets per octave of the log-bucketed histogram: resolution is
/// `2^(1/8)` per bucket, ~9% worst-case relative error on a reported
/// percentile — plenty for latency work at O(1) memory per stream.
const LOG_SUB: usize = 8;
/// Smallest representable value; anything at or below lands in the
/// underflow bucket.
const LOG_MIN: f64 = 1e-6;
/// Hard cap on bucket count (`LOG_MIN * 2^(512/8)` ≈ 1e13): a hostile
/// or NaN-ish sample can never grow the table unboundedly.
const LOG_MAX_BUCKETS: usize = 512;

/// Streaming log-bucketed histogram: O(1) record, O(buckets) percentile,
/// mergeable across streams. This is what per-phase trace aggregation
/// and the farm's gateway-wide percentiles run on — exact sample vectors
/// would grow with session count, this does not.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    underflow: u64,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    fn bucket_of(v: f64) -> usize {
        let idx = ((v / LOG_MIN).log2() * LOG_SUB as f64).floor();
        (idx.max(0.0) as usize).min(LOG_MAX_BUCKETS - 1)
    }

    /// Geometric midpoint of a bucket (the value a percentile reports).
    fn bucket_value(idx: usize) -> f64 {
        LOG_MIN * ((idx as f64 + 0.5) / LOG_SUB as f64).exp2()
    }

    /// Record one observation. Non-finite samples are dropped (they
    /// would poison every percentile).
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
        if v <= LOG_MIN {
            self.underflow += 1;
            return;
        }
        let idx = Self::bucket_of(v);
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        self.sum / self.n as f64
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        self.min
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        self.max
    }

    /// Approximate percentile (`q` in 0..=1): walk buckets to the rank,
    /// report the bucket's geometric midpoint clamped into the observed
    /// [min, max] range.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0) * self.n as f64).ceil().max(1.0) as u64;
        if rank <= self.underflow {
            return self.min;
        }
        let mut seen = self.underflow;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_value(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }
    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    /// Fold another histogram into this one (farm workers → gateway).
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.n += other.n;
        self.sum += other.sum;
        self.underflow += other.underflow;
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, &c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
    }
}

/// Geometric mean (for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Format a duration given in (virtual or wall) milliseconds for tables.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2}s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{ms:.1}ms")
    } else {
        format!("{:.1}us", ms * 1000.0)
    }
}

/// Format a byte count.
pub fn fmt_bytes(b: u64) -> String {
    const KB: u64 = 1024;
    const MB: u64 = 1024 * 1024;
    if b >= MB {
        format!("{:.2}MB", b as f64 / MB as f64)
    } else if b >= KB {
        format!("{:.1}KB", b as f64 / KB as f64)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_nan() {
        assert!(Summary::of(&[]).mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ms(5700.0), "5.70s");
        assert_eq!(fmt_ms(12.5), "12.5ms");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MB");
    }
}
