//! Poll-free readiness primitives for nonblocking sweep loops.
//!
//! The offline build has no `mio`/`epoll` binding crates, so the async
//! gateway runs an epoll-*style* loop the portable way: every socket is
//! `set_nonblocking(true)` and a shard thread sweeps its connection set,
//! attempting reads/writes that either make progress or report
//! [`WouldBlock`](std::io::ErrorKind::WouldBlock). What keeps that from
//! being a busy spin is [`IdleBackoff`]: a sweep that made progress
//! anywhere resets it; consecutive empty sweeps escalate from
//! `yield_now` to capped exponential sleeps, so an idle shard costs
//! microseconds of CPU while a busy shard never sleeps at all.
//!
//! [`read_step`]/[`write_step`] fold the `io::Error` triage (EOF vs
//! would-block vs interrupted vs hard error) into small enums so the
//! per-connection state machine stays a `match`, not a nest of
//! `ErrorKind` checks.

use std::io::{ErrorKind, Read, Write};
use std::time::Duration;

/// True for the `WouldBlock`/`TimedOut` kinds a nonblocking socket uses
/// to say "nothing to do right now".
pub fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Outcome of one nonblocking read attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum ReadStep {
    /// `n` bytes landed in the buffer (`n > 0`).
    Data(usize),
    /// The peer closed its write half (EOF).
    Eof,
    /// Nothing readable right now (`WouldBlock`).
    Idle,
}

/// One nonblocking read, with `Interrupted` retried internally.
pub fn read_step(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<ReadStep> {
    loop {
        match r.read(buf) {
            Ok(0) => return Ok(ReadStep::Eof),
            Ok(n) => return Ok(ReadStep::Data(n)),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if would_block(&e) => return Ok(ReadStep::Idle),
            Err(e) => return Err(e),
        }
    }
}

/// Outcome of one nonblocking write attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum WriteStep {
    /// `n` bytes were accepted by the socket (`n` may be short).
    Wrote(usize),
    /// The send buffer is full (`WouldBlock`): keep write interest.
    Idle,
}

/// One nonblocking write, with `Interrupted` retried internally. A
/// short write is normal — callers track their own cursor.
pub fn write_step(w: &mut impl Write, buf: &[u8]) -> std::io::Result<WriteStep> {
    loop {
        match w.write(buf) {
            Ok(n) => return Ok(WriteStep::Wrote(n)),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if would_block(&e) => return Ok(WriteStep::Idle),
            Err(e) => return Err(e),
        }
    }
}

/// Number of empty sweeps absorbed by `yield_now` before sleeping.
const YIELD_SWEEPS: u32 = 16;

/// Escalating idle strategy for a sweep loop.
///
/// Call [`IdleBackoff::progress`] whenever a sweep moved any byte or
/// accepted any connection, and [`IdleBackoff::idle`] when a whole
/// sweep found nothing. The first [`YIELD_SWEEPS`] idle sweeps only
/// yield the scheduler slice (latency stays sub-microsecond when load
/// resumes immediately); after that, sleeps double from 50µs up to the
/// configured cap, bounding both the idle CPU burn and the worst-case
/// wakeup latency.
#[derive(Debug)]
pub struct IdleBackoff {
    idle_streak: u32,
    cap: Duration,
}

impl IdleBackoff {
    /// A backoff whose sleeps never exceed `cap`.
    pub fn new(cap: Duration) -> IdleBackoff {
        IdleBackoff { idle_streak: 0, cap }
    }

    /// The sweep made progress: snap back to full speed.
    pub fn progress(&mut self) {
        self.idle_streak = 0;
    }

    /// The sweep found nothing: yield or sleep, escalating.
    pub fn idle(&mut self) {
        self.idle_streak = self.idle_streak.saturating_add(1);
        if self.idle_streak <= YIELD_SWEEPS {
            std::thread::yield_now();
            return;
        }
        let doublings = (self.idle_streak - YIELD_SWEEPS - 1).min(12);
        let sleep = Duration::from_micros(50u64 << doublings).min(self.cap);
        std::thread::sleep(sleep);
    }

    /// The sleep [`IdleBackoff::idle`] would take right now (zero while
    /// still in the yield phase). Exposed for tests and tuning.
    pub fn current_delay(&self) -> Duration {
        if self.idle_streak <= YIELD_SWEEPS {
            return Duration::ZERO;
        }
        let doublings = (self.idle_streak - YIELD_SWEEPS).min(12);
        Duration::from_micros(50u64 << (doublings - 1).min(12)).min(self.cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn backoff_escalates_and_resets() {
        let cap = Duration::from_millis(2);
        let mut b = IdleBackoff::new(cap);
        assert_eq!(b.current_delay(), Duration::ZERO);
        // The yield phase never sleeps.
        for _ in 0..YIELD_SWEEPS {
            b.idle_streak += 1;
            assert_eq!(b.current_delay(), Duration::ZERO);
        }
        // Then delays grow but stay capped.
        let mut last = Duration::ZERO;
        for _ in 0..40 {
            b.idle_streak += 1;
            let d = b.current_delay();
            assert!(d >= last, "monotone escalation");
            assert!(d <= cap, "capped at {cap:?}, got {d:?}");
            last = d;
        }
        assert_eq!(last, cap);
        b.progress();
        assert_eq!(b.current_delay(), Duration::ZERO);
    }

    #[test]
    fn idle_sleeps_are_bounded_by_the_cap() {
        let cap = Duration::from_micros(200);
        let mut b = IdleBackoff::new(cap);
        // Drive deep into the sleep phase, then time one idle() call.
        for _ in 0..64 {
            b.idle();
        }
        let t0 = std::time::Instant::now();
        b.idle();
        // Generous bound: the sleep itself is <= 200µs; scheduling
        // noise stays well under 100ms on any CI box.
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn read_write_steps_triage_nonblocking_sockets() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        // Nothing sent yet: Idle, not an error.
        let mut buf = [0u8; 64];
        assert_eq!(read_step(&mut server, &mut buf).unwrap(), ReadStep::Idle);

        // Data flows through as Data(n).
        use std::io::Write as _;
        client.write_all(b"hi").unwrap();
        client.flush().ok();
        loop {
            match read_step(&mut server, &mut buf).unwrap() {
                ReadStep::Data(n) => {
                    assert_eq!(&buf[..n], b"hi");
                    break;
                }
                ReadStep::Idle => std::thread::yield_now(),
                ReadStep::Eof => panic!("premature eof"),
            }
        }

        // Writes report progress; a closed peer reads as Eof.
        match write_step(&mut server, b"yo").unwrap() {
            WriteStep::Wrote(n) => assert!(n > 0),
            WriteStep::Idle => panic!("tiny write blocked"),
        }
        drop(client);
        loop {
            match read_step(&mut server, &mut buf).unwrap() {
                ReadStep::Eof => break,
                ReadStep::Idle => std::thread::yield_now(),
                ReadStep::Data(_) => {}
            }
        }
    }
}
