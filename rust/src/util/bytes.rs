//! Network-byte-order (big-endian) wire I/O.
//!
//! The paper's capture format stores field values in network byte order so
//! captures are portable across phone/clone processor architectures
//! (§4.1). All migration wire formats in `migration/format.rs` and the
//! node-manager protocol go through this reader/writer pair.

use crate::error::{CloneCloudError, Result};

/// Append-only big-endian writer.
#[derive(Debug, Default, Clone)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        WireWriter {
            buf: Vec::with_capacity(cap),
        }
    }

    /// Reuse an existing buffer's allocation: the vector is cleared but
    /// keeps its capacity, so a session-lifetime scratch buffer encodes
    /// every trip without re-growing from zero. [`WireWriter::into_vec`]
    /// hands the (refilled) buffer back.
    pub fn from_vec(mut buf: Vec<u8>) -> Self {
        buf.clear();
        WireWriter { buf }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
    pub fn put_i64(&mut self, v: i64) {
        self.put_u64(v as u64);
    }
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }
    /// Write a collection count as its u32 wire form, or fail with a
    /// typed `Wire` error when the count does not fit. The unchecked
    /// `put_u32(n as u32)` idiom silently truncates an oversized
    /// collection into a frame whose count disagrees with its body —
    /// the receiver then misparses bytes instead of rejecting them.
    /// Every encoder with a variable-count section goes through here.
    pub fn put_count(&mut self, n: usize) -> Result<()> {
        let v = u32::try_from(n).map_err(|_| {
            CloneCloudError::Wire(format!("collection count {n} exceeds the u32 wire limit"))
        })?;
        self.put_u32(v);
        Ok(())
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }
}

/// Big-endian cursor reader with explicit truncation errors.
#[derive(Debug)]
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(CloneCloudError::Wire(format!(
                "truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Validate an entry count against the bytes actually remaining
    /// (each entry consumes at least `min_entry_bytes` on the wire), so
    /// a corrupt or hostile length can never force a huge pre-allocation
    /// before decoding fails naturally.
    pub fn checked_count(&self, n: usize, min_entry_bytes: usize) -> Result<usize> {
        if n > self.remaining() / min_entry_bytes.max(1) {
            return Err(CloneCloudError::Wire(format!(
                "count {n} exceeds the {} bytes remaining",
                self.remaining()
            )));
        }
        Ok(n)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn get_u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_be_bytes([b[0], b[1]]))
    }
    pub fn get_u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
    }
    pub fn get_u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_be_bytes(a))
    }
    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(self.get_u64()? as i64)
    }
    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }
    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_u32()?))
    }
    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b).map_err(|e| CloneCloudError::Wire(format!("bad utf-8: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = WireWriter::new();
        w.put_u8(7);
        w.put_u16(0xBEEF);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 3);
        w.put_i64(-42);
        w.put_f64(3.25);
        w.put_f32(-1.5);
        w.put_bytes(b"abc");
        w.put_str("m\u{e9}thode");
        let buf = w.into_vec();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u16().unwrap(), 0xBEEF);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_i64().unwrap(), -42);
        assert_eq!(r.get_f64().unwrap(), 3.25);
        assert_eq!(r.get_f32().unwrap(), -1.5);
        assert_eq!(r.get_bytes().unwrap(), b"abc");
        assert_eq!(r.get_str().unwrap(), "m\u{e9}thode");
        assert!(r.is_done());
    }

    #[test]
    fn big_endian_on_the_wire() {
        let mut w = WireWriter::new();
        w.put_u32(1);
        assert_eq!(w.as_slice(), &[0, 0, 0, 1], "network byte order");
    }

    #[test]
    fn put_count_matches_put_u32_and_rejects_overflow() {
        let mut w = WireWriter::new();
        w.put_count(3).unwrap();
        let mut w2 = WireWriter::new();
        w2.put_u32(3);
        assert_eq!(w.as_slice(), w2.as_slice(), "in-range counts stay bit-identical");
        assert!(w.put_count(u32::MAX as usize).is_ok());
        // Counts past u32::MAX must error, never truncate. (usize is 64-bit
        // on every supported target; the check is what makes that explicit.)
        let err = w.put_count(u32::MAX as usize + 1).unwrap_err().to_string();
        assert!(err.contains("u32 wire limit"), "{err}");
    }

    #[test]
    fn truncation_is_an_error() {
        let mut r = WireReader::new(&[0, 0]);
        assert!(r.get_u32().is_err());
        let mut r2 = WireReader::new(&[0, 0, 0, 9, b'a']);
        assert!(r2.get_bytes().is_err(), "length prefix beyond buffer");
    }
}
