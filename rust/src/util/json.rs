//! Minimal JSON substrate (the environment has no serde facade crate).
//!
//! Parses and emits the JSON subset the repository needs: the artifact
//! manifest written by `python/compile/aot.py`, the partition database,
//! and configuration files. Numbers are kept as f64 plus an i64 fast
//! path; strings support the standard escapes.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError {
            offset: self.pos,
            message: msg.to_string(),
        })
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{s}'"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| JsonError {
                                        offset: self.pos,
                                        message: "bad \\u escape".into(),
                                    })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| JsonError {
                                offset: self.pos,
                                message: "bad \\u escape".into(),
                            })?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).map_err(|_| {
                        JsonError {
                            offset: self.pos,
                            message: "invalid utf-8".into(),
                        }
                    })?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError {
                offset: start,
                message: format!("bad number '{s}'"),
            })
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn emit_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9.0e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                emit_into(x, out);
            }
            out.push('}');
        }
    }
}

/// Serialize a JSON value compactly.
pub fn emit(v: &Json) -> String {
    let mut out = String::new();
    emit_into(v, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"migr":{"net":"wifi","r":[1,0,1]},"t":12.5,"s":"a\"b"}"#;
        let v = parse(src).unwrap();
        let emitted = emit(&v);
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest() {
        // The actual artifact manifest format written by aot.py.
        let src = r#"{
          "categorize": {
            "file": "categorize.hlo.txt",
            "inputs": [{"shape": [8, 256], "dtype": "float32"}],
            "outputs": [{"shape": [8, 512], "dtype": "float32"}]
          }
        }"#;
        let v = parse(src).unwrap();
        let cat = v.get("categorize");
        assert_eq!(cat.get("file").as_str(), Some("categorize.hlo.txt"));
        let ins = cat.get("inputs").as_arr().unwrap();
        assert_eq!(ins[0].get("shape").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(emit(&Json::Num(3.0)), "3");
        assert_eq!(emit(&Json::Num(3.25)), "3.25");
    }
}
