//! Deterministic PRNG substrate (the environment has no `rand` crate).
//!
//! XorShift64* — small, fast, and good enough for workload generation and
//! the property-test harness. All randomness in the repository flows
//! through this type so every experiment is reproducible from a seed.

/// XorShift64* pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped (XorShift
    /// has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform u64 in `[0, bound)`; `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift rejection-free mapping; bias is negligible for
        // simulation purposes (bound << 2^64).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random byte.
    pub fn byte(&mut self) -> u8 {
        (self.next_u64() >> 56) as u8
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for b in buf.iter_mut() {
            *b = self.byte();
        }
    }

    /// Choose `k` distinct indices from `[0, n)` (k <= n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Floyd's algorithm.
        let mut out: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
        out
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_reasonable() {
        let mut r = Rng::new(3);
        let mean = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let mut xs = r.choose_distinct(20, 10);
            xs.sort_unstable();
            xs.dedup();
            assert_eq!(xs.len(), 10);
            assert!(xs.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zero_seed_not_stuck() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
        assert_ne!(r.next_u64(), r.next_u64());
    }
}
