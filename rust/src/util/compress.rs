//! Dependency-free frame compression (wire-efficiency layer).
//!
//! The offline build environment has no flate2/zstd, so the negotiated
//! frame codec is hand-rolled: an LZ77-style byte-oriented scheme with a
//! built-in RLE path (a match at distance 1 is a run). Capture capsules
//! compress extremely well — zero-heavy arrays, interned-string tables,
//! repeated section headers — and the codec favors decode simplicity
//! over ratio: two op kinds, strict bounds checks, deterministic output.
//!
//! Stream format (a raw token stream; framing/length live one layer up
//! in `nodemanager::protocol`):
//!
//! * op byte `< 0x80`: a literal run of `op + 1` bytes (1..=128) follows;
//! * op byte `>= 0x80`: a back-reference of length `(op & 0x7F) + 4`
//!   (4..=131) at a 2-byte big-endian distance (1..=65535) into the
//!   already-produced output. Overlapping copies are allowed, so
//!   distance 1 encodes a run (the RLE fallback).
//!
//! Decoding is strict: truncated runs, zero/overlong distances, and any
//! output-length disagreement with the declared raw length are errors —
//! a strict prefix of a valid stream never decodes (see the prop tests).

use crate::error::{CloneCloudError, Result};

/// Shortest back-reference worth emitting (a match op costs 3 bytes).
const MIN_MATCH: usize = 4;
/// Longest back-reference one op can carry.
const MAX_MATCH: usize = 131;
/// Farthest back an op can reach (u16 distance).
const MAX_DIST: usize = 65_535;
const HASH_BITS: u32 = 15;

#[inline]
fn hash4(w: &[u8]) -> usize {
    let v = u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

fn flush_literals(out: &mut Vec<u8>, mut lits: &[u8]) {
    while !lits.is_empty() {
        let n = lits.len().min(128);
        out.push((n - 1) as u8);
        out.extend_from_slice(&lits[..n]);
        lits = &lits[n..];
    }
}

/// Compress `input` into the token stream. Never fails; worst case the
/// output is `input` plus one literal-run op byte per 128 input bytes
/// (the frame layer falls back to the raw bytes when compression loses).
pub fn compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 16);
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i + MIN_MATCH <= input.len() {
        let h = hash4(&input[i..i + 4]);
        let cand = table[h];
        table[h] = i;

        // Best back-reference: the hash candidate, or the distance-1 run
        // (RLE) — whichever extends further.
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if cand != usize::MAX
            && i - cand <= MAX_DIST
            && input[cand..cand + 4] == input[i..i + 4]
        {
            let mut l = 4;
            while i + l < input.len() && l < MAX_MATCH && input[cand + l] == input[i + l] {
                l += 1;
            }
            best_len = l;
            best_dist = i - cand;
        }
        if i > 0 {
            let b = input[i - 1];
            let mut l = 0;
            while i + l < input.len() && l < MAX_MATCH && input[i + l] == b {
                l += 1;
            }
            if l >= MIN_MATCH && l > best_len {
                best_len = l;
                best_dist = 1;
            }
        }

        if best_len >= MIN_MATCH {
            flush_literals(&mut out, &input[lit_start..i]);
            out.push(0x80 | (best_len - MIN_MATCH) as u8);
            out.extend_from_slice(&(best_dist as u16).to_be_bytes());
            i += best_len;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    flush_literals(&mut out, &input[lit_start..]);
    out
}

/// Decompress a token stream that must produce exactly `expected_len`
/// bytes. Any structural defect — truncated literal run, truncated or
/// out-of-range distance, output over- or under-shooting the declared
/// length — is a clean `Wire` error, never a panic.
pub fn decompress(input: &[u8], expected_len: usize) -> Result<Vec<u8>> {
    // Cap the up-front allocation so a garbage length cannot OOM us:
    // `expected_len` is an unvalidated wire claim until the stream has
    // actually produced that many bytes, so it may reserve at most the
    // one protocol-wide pre-validation cap.
    let mut out =
        Vec::with_capacity(expected_len.min(crate::nodemanager::protocol::MAX_PREVALIDATION_ALLOC));
    let mut i = 0usize;
    while i < input.len() {
        let op = input[i];
        i += 1;
        if op < 0x80 {
            let n = op as usize + 1;
            if i + n > input.len() {
                return Err(CloneCloudError::Wire(format!(
                    "compressed stream truncated inside a {n}-byte literal run"
                )));
            }
            out.extend_from_slice(&input[i..i + n]);
            i += n;
        } else {
            let len = (op & 0x7F) as usize + MIN_MATCH;
            if i + 2 > input.len() {
                return Err(CloneCloudError::Wire(
                    "compressed stream truncated inside a match distance".into(),
                ));
            }
            let dist = u16::from_be_bytes([input[i], input[i + 1]]) as usize;
            i += 2;
            if dist == 0 || dist > out.len() {
                return Err(CloneCloudError::Wire(format!(
                    "match distance {dist} outside the {} produced bytes",
                    out.len()
                )));
            }
            let mut k = out.len() - dist;
            for _ in 0..len {
                let b = out[k];
                out.push(b);
                k += 1;
            }
        }
        if out.len() > expected_len {
            return Err(CloneCloudError::Wire(format!(
                "compressed stream produced {} bytes, declared {expected_len}",
                out.len()
            )));
        }
    }
    if out.len() != expected_len {
        return Err(CloneCloudError::Wire(format!(
            "compressed stream produced {} bytes, declared {expected_len}",
            out.len()
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, ensure_eq, forall, PropConfig};
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        decompress(&compress(data), data.len()).expect("roundtrip")
    }

    #[test]
    fn unit_roundtrips() {
        for data in [
            Vec::new(),
            vec![7u8],
            vec![0u8; 10_000],
            b"abcabcabcabcabcabc".to_vec(),
            (0u8..=255).collect::<Vec<_>>(),
        ] {
            assert_eq!(roundtrip(&data), data);
        }
    }

    #[test]
    fn runs_compress_hard() {
        // One 3-byte match op covers at most MAX_MATCH (131) bytes, so
        // a pure run tops out at ~43.7x — gate on 40x.
        let data = vec![0u8; 64 * 1024];
        let c = compress(&data);
        assert!(
            c.len() * 40 < data.len(),
            "RLE path: 64 KiB of zeros -> {} bytes",
            c.len()
        );
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn wrong_declared_length_is_rejected() {
        let data = b"hello hello hello hello".to_vec();
        let c = compress(&data);
        assert!(decompress(&c, data.len() + 1).is_err());
        assert!(decompress(&c, data.len().saturating_sub(1)).is_err());
    }

    /// A mixed corpus: random bytes, zero runs, repeated small patterns,
    /// and text-like content — the shapes capture capsules actually have.
    fn gen_corpus(rng: &mut Rng) -> Vec<u8> {
        let n = rng.index(4096);
        match rng.index(4) {
            0 => {
                let mut b = vec![0u8; n];
                rng.fill_bytes(&mut b);
                b
            }
            1 => vec![rng.byte(); n],
            2 => {
                let pat: Vec<u8> = (0..rng.index(8) + 1).map(|_| rng.byte()).collect();
                (0..n).map(|i| pat[i % pat.len()]).collect()
            }
            _ => (0..n).map(|_| b'a' + rng.byte() % 26).collect(),
        }
    }

    #[test]
    fn prop_roundtrip() {
        forall(
            PropConfig {
                seed: 0xC0_DEC_01,
                cases: 150,
            },
            gen_corpus,
            |data| ensure_eq(roundtrip(data), data.clone(), "decompress(compress(d))"),
        );
    }

    #[test]
    fn prop_strict_prefixes_never_decode() {
        // Every op emits at least one output byte, so a strict prefix of
        // a valid stream either truncates an op or undershoots the
        // declared raw length — both are errors.
        forall(
            PropConfig {
                seed: 0xC0_DEC_02,
                cases: 150,
            },
            |rng| {
                let data = gen_corpus(rng);
                let c = compress(&data);
                let cut = rng.index(c.len().max(1));
                (c, cut, data.len())
            },
            |(c, cut, raw_len)| {
                if *raw_len == 0 {
                    return Ok(()); // empty stream has no strict prefix
                }
                ensure(decompress(&c[..*cut], *raw_len).is_err(), "prefix decoded")
            },
        );
    }

    #[test]
    fn prop_garbage_never_panics() {
        forall(
            PropConfig {
                seed: 0xC0_DEC_03,
                cases: 300,
            },
            |rng| {
                let mut b = vec![0u8; rng.index(512)];
                rng.fill_bytes(&mut b);
                let declared = rng.index(1024);
                (b, declared)
            },
            |(bytes, declared)| {
                let _ = decompress(bytes, *declared); // Ok or Err; no panic
                Ok(())
            },
        );
    }
}
