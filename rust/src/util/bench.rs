//! Bench harness substrate (the environment has no criterion crate).
//!
//! Provides warmup + timed iteration + summary statistics and a paper-table
//! printer. Every `rust/benches/*.rs` target (`harness = false`) drives its
//! measurements through this module so output formats are uniform and
//! comparable across runs (EXPERIMENTS.md copies these tables verbatim).

use std::time::Instant;

use super::stats::Summary;

/// Measure wall-clock milliseconds of `f` over `iters` timed iterations
/// after `warmup` untimed ones. Returns per-iteration samples.
pub fn time_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples
}

/// One named measurement with its summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

/// Run a named benchmark and print a one-line summary.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    let samples = time_ms(warmup, iters, f);
    let summary = Summary::of(&samples);
    println!(
        "{name:<44} mean {:>10.3}ms  p50 {:>10.3}ms  p95 {:>10.3}ms  (n={})",
        summary.mean, summary.p50, summary.p95, summary.n
    );
    BenchResult {
        name: name.to_string(),
        summary,
    }
}

/// Whether the benches should run in CI smoke mode (reduced workloads,
/// relaxed-but-present assertions): set `CC_BENCH_SMOKE=1`.
pub fn smoke_mode() -> bool {
    std::env::var_os("CC_BENCH_SMOKE").is_some()
}

/// Append one JSON object line to the file named by `CC_BENCH_JSON` (a
/// no-op when unset). The CI bench-trajectory job collects these lines
/// into the `BENCH_PR.json` artifact (`jq -s`), so the perf trajectory
/// is recorded per PR instead of evaporating with the job log. String
/// labels first, then numeric fields; non-finite numbers are written as
/// 0 to keep the output valid JSON.
pub fn emit_json(bench: &str, labels: &[(&str, &str)], fields: &[(&str, f64)]) {
    let path = match std::env::var_os("CC_BENCH_JSON") {
        Some(p) => p,
        None => return,
    };
    let mut line = format!("{{\"bench\":\"{bench}\"");
    for (k, v) in labels {
        line.push_str(&format!(",\"{k}\":\"{v}\""));
    }
    for (k, v) in fields {
        let v = if v.is_finite() { *v } else { 0.0 };
        line.push_str(&format!(",\"{k}\":{v}"));
    }
    line.push_str("}\n");
    use std::io::Write;
    let file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path);
    match file {
        Ok(mut f) => {
            if let Err(e) = f.write_all(line.as_bytes()) {
                eprintln!("emit_json: write {path:?}: {e}");
            }
        }
        Err(e) => eprintln!("emit_json: open {path:?}: {e}"),
    }
}

/// Fixed-width table printer for paper-style tables.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * (ncols - 1);
        println!("\n=== {} ===", self.title);
        let head: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:<w$}", h, w = widths[i]))
            .collect();
        println!("{}", head.join(" | "));
        println!("{}", "-".repeat(total + 2));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("{}", line.join(" | "));
        }
    }
}

/// Prevent the optimizer from discarding a value (no `std::hint::black_box`
/// guarantees needed beyond read-volatile semantics).
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66; use it directly.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ms_returns_requested_samples() {
        let s = time_ms(1, 5, || {
            black_box(1 + 1);
        });
        assert_eq!(s.len(), 5);
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic]
    fn table_panics_on_wrong_row_len() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
