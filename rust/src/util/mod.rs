//! Shared substrates: PRNG, JSON, wire I/O, stats, bench + property
//! harnesses. These replace crates unavailable in the offline build
//! environment (rand, serde, criterion, proptest) — see DESIGN.md §2.

pub mod bench;
pub mod bytes;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
