//! Shared substrates: PRNG, JSON, wire I/O, stats, lazy statics, bench +
//! property harnesses. These replace crates unavailable in the offline
//! build environment (rand, serde, criterion, proptest, once_cell) — see
//! DESIGN.md §2.

pub mod bench;
pub mod bytes;
pub mod compress;
pub mod fuzz;
pub mod json;
pub mod lazy;
pub mod prop;
pub mod readiness;
pub mod rng;
pub mod stats;
