//! Lazy statics over `std::sync::OnceLock` (the offline environment has
//! no once_cell crate). Only the subset the codebase needs: a
//! const-constructible, `Deref`-transparent lazy cell initialized from a
//! non-capturing closure.

use std::ops::Deref;
use std::sync::OnceLock;

/// A value initialized on first access, safe to use in a `static`.
pub struct Lazy<T> {
    cell: OnceLock<T>,
    init: fn() -> T,
}

impl<T> Lazy<T> {
    /// `init` must be a non-capturing closure (it coerces to `fn()`).
    pub const fn new(init: fn() -> T) -> Lazy<T> {
        Lazy {
            cell: OnceLock::new(),
            init,
        }
    }

    /// Force initialization and return the value.
    pub fn force(this: &Lazy<T>) -> &T {
        this.cell.get_or_init(this.init)
    }
}

impl<T> Deref for Lazy<T> {
    type Target = T;

    fn deref(&self) -> &T {
        Lazy::force(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static CALLS: AtomicUsize = AtomicUsize::new(0);
    static CELL: Lazy<Vec<u32>> = Lazy::new(|| {
        CALLS.fetch_add(1, Ordering::SeqCst);
        vec![1, 2, 3]
    });

    #[test]
    fn initializes_once_and_derefs() {
        assert_eq!(CELL.len(), 3);
        assert_eq!(CELL[2], 3);
        assert_eq!(CALLS.load(Ordering::SeqCst), 1, "single initialization");
    }
}
