//! Workload sizes: the paper's three input points per application.

/// Table 1 input sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Size {
    Small,
    Medium,
    Large,
}

impl Size {
    pub fn all() -> [Size; 3] {
        [Size::Small, Size::Medium, Size::Large]
    }
}

/// Virus scanner: total file-system bytes (paper: 100 KB / 1 MB / 10 MB).
pub fn virus_fs_bytes(size: Size) -> usize {
    match size {
        Size::Small => 100 * 1024,
        Size::Medium => 1024 * 1024,
        Size::Large => 10 * 1024 * 1024,
    }
}

/// Image search: number of images (paper: 1 / 10 / 100).
pub fn image_count(size: Size) -> usize {
    match size {
        Size::Small => 1,
        Size::Medium => 10,
        Size::Large => 100,
    }
}

/// Behavior profiling: DMOZ tree depth (paper: 3 / 4 / 5).
pub fn behavior_depth(size: Size) -> usize {
    match size {
        Size::Small => 3,
        Size::Medium => 4,
        Size::Large => 5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_paper() {
        assert_eq!(virus_fs_bytes(Size::Large), 10 * 1024 * 1024);
        assert_eq!(image_count(Size::Medium), 10);
        assert_eq!(behavior_depth(Size::Small), 3);
        assert_eq!(Size::all().len(), 3);
    }
}
