//! Image search (paper §6): find all faces in the phone's photo
//! directory using a natively-implemented detection library — the
//! paper's canonical "native everywhere" API (Android's face detector
//! exists on the clone too, so the search loop may migrate).
//!
//! Classes: `GalleryUI` (main + pinned UI), `Finder` (the search loop +
//! fs group), `Detector` (the everywhere compute native). State ballast:
//! the thumbnail cache (~600 KB).

use std::sync::Arc;

use crate::util::lazy::Lazy;

use crate::appvm::assembler::assemble;
use crate::appvm::natives::shapes;
use crate::appvm::process::Process;
use crate::appvm::value::Value;
use crate::appvm::Program;
use crate::error::{CloneCloudError, Result};
use crate::util::rng::Rng;
use crate::vfs::SimFs;

use super::workload::{image_count, Size};
use super::{read_static_int, App};

/// Detection threshold (see `make_filters`: planted faces respond ~8,
/// noise responds within ~4 sigma of 0 at sigma ~1.1).
pub const THRESHOLD: f64 = 4.0;

/// Images containing a planted face per workload.
pub fn planted_faces(size: Size) -> usize {
    match size {
        Size::Small => 1,
        Size::Medium => 3,
        Size::Large => 10,
    }
}

const SRC: &str = r#"
class GalleryUI app
  method main nargs=0 regs=4
    invokev GalleryUI.uiinit
    invoke r0 Finder.find_all
    puts Finder.faces r0
    invokev GalleryUI.show r0
    retv
  end
  method uiinit nargs=0 regs=0 native=ui.init
  method show nargs=1 regs=1 native=ui.show
end
class Finder app
  static filters
  static thresh
  static cache
  static faces
  method find_all nargs=0 regs=10
    invoke r0 Finder.count
    const r1 0
    const r2 0
  iloop:
    ifge r1 r0 @done
    invoke r3 Finder.search_one r1
    add r2 r2 r3
    const r4 1
    add r1 r1 r4
    goto @iloop
  done:
    ret r2
  end
  method search_one nargs=1 regs=8
    const r1 0
    const r2 4096
    invoke r3 Finder.read r0 r1 r2
    gets r4 Finder.filters
    gets r5 Finder.thresh
    invoke r6 Detector.detect r3 r4 r5
    ret r6
  end
  method count nargs=0 regs=0 native=fs.count natstate
  method read nargs=3 regs=3 native=fs.read natstate
end
class Detector app
  method detect nargs=3 regs=3 native=compute.face_detect
end
"#;

static PROGRAM: Lazy<Arc<Program>> = Lazy::new(|| {
    let p = assemble(SRC).expect("image search assembles");
    crate::appvm::verifier::verify_program(&p).expect("image search verifies");
    Arc::new(p)
});

/// Zero-mean filter bank, shared between fs generation (planting) and
/// install.
fn make_filters(rng: &mut Rng) -> Vec<f32> {
    let mut filters = vec![0f32; shapes::PATCH * shapes::PATCH * shapes::N_FILTERS];
    for f in 0..shapes::N_FILTERS {
        let mut col = vec![0f32; 64];
        let mut mean = 0.0;
        for c in col.iter_mut() {
            *c = rng.range_f32(-1.0, 1.0);
            mean += *c;
        }
        mean /= 64.0;
        for (k, c) in col.iter().enumerate() {
            filters[k * shapes::N_FILTERS + f] = c - mean;
        }
    }
    filters
}

/// A face pattern: filter 2's weights mapped into bytes so the detector
/// responds strongly (response ~ 0.39 |w|^2 ~ 8 >> threshold 4).
fn face_pattern(filters: &[f32]) -> Vec<u8> {
    (0..64)
        .map(|k| {
            let w = filters[k * shapes::N_FILTERS + 2];
            (128.0 + 100.0 * w).clamp(0.0, 255.0) as u8
        })
        .collect()
}

/// The image-search app.
pub struct ImageSearch;

impl App for ImageSearch {
    fn name(&self) -> &'static str {
        "image"
    }

    fn input_label(&self, size: Size) -> String {
        match size {
            Size::Small => "1 image".into(),
            Size::Medium => "10 images".into(),
            Size::Large => "100 images".into(),
        }
    }

    fn program(&self) -> Arc<Program> {
        PROGRAM.clone()
    }

    fn make_fs(&self, size: Size, rng: &mut Rng) -> SimFs {
        let filters = make_filters(rng);
        let pattern = face_pattern(&filters);
        SimFs::generate_gallery(
            rng,
            image_count(size),
            shapes::IMG,
            &pattern,
            planted_faces(size).min(image_count(size)),
        )
    }

    fn install(&self, p: &mut Process, _size: Size, rng: &mut Rng) -> Result<()> {
        let filters = make_filters(rng);
        let cid = p
            .program
            .class_id("Finder")
            .ok_or_else(|| CloneCloudError::program("no Finder class"))?;
        let class = p.program.class(cid);
        let f_slot = class.static_id("filters").unwrap() as usize;
        let t_slot = class.static_id("thresh").unwrap() as usize;
        let c_slot = class.static_id("cache").unwrap() as usize;
        let arr_class = p.array_class;
        let f_obj = p.heap.alloc_float_array(arr_class, filters);
        let mut cache = vec![0u8; 600 * 1024];
        rng.fill_bytes(&mut cache);
        let c_obj = p.heap.alloc_byte_array(arr_class, cache);
        p.statics[cid.0 as usize][f_slot] = Value::Ref(f_obj);
        p.statics[cid.0 as usize][t_slot] = Value::Float(THRESHOLD);
        p.statics[cid.0 as usize][c_slot] = Value::Ref(c_obj);
        Ok(())
    }

    fn check(&self, p: &Process, size: Size) -> Result<String> {
        let faces = read_static_int(p, "Finder", "faces")
            .ok_or_else(|| CloneCloudError::vm("no face count"))?;
        let planted = planted_faces(size).min(image_count(size)) as i64;
        if faces < planted {
            return Err(CloneCloudError::vm(format!(
                "found {faces} faces, planted {planted}"
            )));
        }
        Ok(format!("{faces} faces found"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::natives::RustCompute;
    use crate::apps::build_process;
    use crate::config::Config;
    use crate::device::Location;
    use crate::exec::run_monolithic;

    fn cfg() -> Config {
        Config {
            zygote_objects: 100,
            ..Config::default()
        }
    }

    #[test]
    fn finds_planted_faces_monolithically() {
        let app = ImageSearch;
        let mut p = build_process(
            &app, app.program(), Size::Medium, &cfg(),
            Location::Mobile, Arc::new(RustCompute), false,
        )
        .unwrap();
        run_monolithic(&mut p).unwrap();
        let msg = app.check(&p, Size::Medium).unwrap();
        assert!(msg.contains("faces found"), "{msg}");
        let n = read_static_int(&p, "Finder", "faces").unwrap();
        assert!(n >= 3, "at least the planted faces: {n}");
        assert!(n <= 40, "noise must not explode detections: {n}");
    }

    #[test]
    fn one_image_run_lands_at_paper_scale() {
        // Paper: 1 image on the phone = 22.2 s.
        let app = ImageSearch;
        let mut p = build_process(
            &app, app.program(), Size::Small, &cfg(),
            Location::Mobile, Arc::new(RustCompute), false,
        )
        .unwrap();
        let out = run_monolithic(&mut p).unwrap();
        let secs = out.virtual_ms / 1e3;
        assert!(secs > 10.0 && secs < 40.0, "1-image phone run = {secs:.1}s");
    }
}
