//! Virus scanner (paper §6): scans the phone file system against a
//! signature library, one file at a time, in 4 KiB chunks with
//! SIG_LEN-1-byte overlap so boundary-straddling signatures are found
//! exactly once.
//!
//! Classes: `VirusUI` (main + pinned UI natives), `Scanner` (the scan
//! driver + native-state fs methods — the V_Nat_C group), `Matcher`
//! (the everywhere compute native). The partitioner's interesting choice
//! is `Scanner.scan_all`: offloading it drags the fs group along
//! (legal — the fs is synchronized) while `VirusUI` stays pinned.
//!
//! Calibration (DESIGN.md §3): one `compute.scan_chunk` call models
//! scanning a 4 KiB chunk against the paper's 1000-signature library
//! (our artifact holds one 128-signature panel; the virtual cost is
//! calibrated to the full library so Table 1's phone column lands at the
//! paper's scale). State ballast: the scanner's quarantine/report cache
//! (~800 KB) — the app state a migration must carry.

use std::sync::Arc;

use crate::util::lazy::Lazy;

use crate::appvm::assembler::assemble;
use crate::appvm::natives::shapes;
use crate::appvm::process::Process;
use crate::appvm::value::Value;
use crate::appvm::Program;
use crate::error::{CloneCloudError, Result};
use crate::util::rng::Rng;
use crate::vfs::SimFs;

use super::workload::{virus_fs_bytes, Size};
use super::{read_static_int, App};

/// Chunk stride: 4096 - (SIG_LEN - 1) so a signature crossing a chunk
/// boundary is seen whole in exactly one chunk.
pub const STRIDE: usize = shapes::CHUNK - (shapes::SIG_LEN - 1);

/// Signatures planted into the corpus per workload.
pub const PLANTS: usize = 3;

const SRC: &str = r#"
class VirusUI app
  method main nargs=0 regs=4
    invokev VirusUI.uiinit
    invoke r0 Scanner.scan_all
    puts Scanner.total r0
    invokev VirusUI.show r0
    retv
  end
  method uiinit nargs=0 regs=0 native=ui.init
  method show nargs=1 regs=1 native=ui.show
end
class Scanner app
  static sigs
  static cache
  static total
  method scan_all nargs=0 regs=10
    invoke r0 Scanner.count
    const r1 0
    const r2 0
  floop:
    ifge r1 r0 @done
    invoke r3 Scanner.scan_file r1
    add r2 r2 r3
    const r4 1
    add r1 r1 r4
    goto @floop
  done:
    ret r2
  end
  method scan_file nargs=1 regs=12
    invoke r1 Scanner.fsize r0
    const r2 0
    const r3 0
    gets r4 Scanner.sigs
  chunks:
    ifge r2 r1 @fdone
    const r5 4096
    invoke r6 Scanner.read r0 r2 r5
    invoke r7 Matcher.match r6 r4
    add r3 r3 r7
    const r5 4081
    add r2 r2 r5
    goto @chunks
  fdone:
    ret r3
  end
  method count nargs=0 regs=0 native=fs.count natstate
  method fsize nargs=1 regs=1 native=fs.size natstate
  method read nargs=3 regs=3 native=fs.read natstate
end
class Matcher app
  method match nargs=2 regs=2 native=compute.scan_chunk
end
"#;

static PROGRAM: Lazy<Arc<Program>> = Lazy::new(|| {
    let p = assemble(SRC).expect("virus scanner assembles");
    crate::appvm::verifier::verify_program(&p).expect("virus scanner verifies");
    Arc::new(p)
});

/// Deterministic signature library (shared by fs generation + install).
fn make_sigs(rng: &mut Rng) -> Vec<u8> {
    let mut sigs = vec![0u8; shapes::SIG_LEN * shapes::N_SIGS];
    rng.fill_bytes(&mut sigs);
    sigs
}

/// Column `s` of the signature matrix as raw bytes.
fn sig_column(sigs: &[u8], s: usize) -> Vec<u8> {
    (0..shapes::SIG_LEN)
        .map(|k| sigs[k * shapes::N_SIGS + s])
        .collect()
}

/// The virus-scanner app.
pub struct VirusScan;

impl App for VirusScan {
    fn name(&self) -> &'static str {
        "virus"
    }

    fn input_label(&self, size: Size) -> String {
        match size {
            Size::Small => "100KB".into(),
            Size::Medium => "1MB".into(),
            Size::Large => "10MB".into(),
        }
    }

    fn program(&self) -> Arc<Program> {
        PROGRAM.clone()
    }

    fn make_fs(&self, size: Size, rng: &mut Rng) -> SimFs {
        // Same rng stream ordering as install(): signatures first.
        let sigs = make_sigs(rng);
        let plants: Vec<Vec<u8>> = (0..PLANTS)
            .map(|i| sig_column(&sigs, 7 + 11 * i))
            .collect();
        SimFs::generate_corpus(rng, virus_fs_bytes(size), 32 * 1024, &plants)
    }

    fn install(&self, p: &mut Process, _size: Size, rng: &mut Rng) -> Result<()> {
        let sigs_bytes = make_sigs(rng);
        let sigs_f32: Vec<f32> = sigs_bytes.iter().map(|&b| b as f32).collect();
        let cid = p
            .program
            .class_id("Scanner")
            .ok_or_else(|| CloneCloudError::program("no Scanner class"))?;
        let class = p.program.class(cid);
        let sigs_slot = class.static_id("sigs").unwrap() as usize;
        let cache_slot = class.static_id("cache").unwrap() as usize;
        let arr_class = p.array_class;
        let sigs_obj = p.heap.alloc_float_array(arr_class, sigs_f32);
        // Quarantine/report cache: app-state ballast a migration carries.
        let mut cache = vec![0u8; 800 * 1024];
        rng.fill_bytes(&mut cache);
        let cache_obj = p.heap.alloc_byte_array(arr_class, cache);
        p.statics[cid.0 as usize][sigs_slot] = Value::Ref(sigs_obj);
        p.statics[cid.0 as usize][cache_slot] = Value::Ref(cache_obj);
        Ok(())
    }

    fn check(&self, p: &Process, _size: Size) -> Result<String> {
        let total = read_static_int(p, "Scanner", "total")
            .ok_or_else(|| CloneCloudError::vm("no scan total"))?;
        // All planted signatures must be found; random 16-byte collisions
        // are cryptographically unlikely.
        if total != PLANTS as i64 {
            return Err(CloneCloudError::vm(format!(
                "virus scan found {total} hits, planted {PLANTS}"
            )));
        }
        Ok(format!("{total} infected locations"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::natives::RustCompute;
    use crate::apps::build_process;
    use crate::config::Config;
    use crate::device::Location;
    use crate::exec::run_monolithic;

    #[test]
    fn monolithic_run_finds_planted_signatures() {
        let app = VirusScan;
        let cfg = Config {
            zygote_objects: 200, // keep the unit test light
            ..Config::default()
        };
        let mut p = build_process(
            &app,
            app.program(),
            Size::Small,
            &cfg,
            Location::Mobile,
            Arc::new(RustCompute),
            false,
        )
        .unwrap();
        let out = run_monolithic(&mut p).unwrap();
        let msg = app.check(&p, Size::Small).unwrap();
        assert!(msg.contains("3 infected"), "{msg}");
        assert!(out.virtual_ms > 0.0);
        assert!(p.env.ui_log.iter().any(|l| l.contains("ui.show int:3")));
    }

    #[test]
    fn phone_vs_clone_ratio_is_papers() {
        let app = VirusScan;
        let cfg = Config {
            zygote_objects: 100,
            ..Config::default()
        };
        let mut phone = build_process(
            &app, app.program(), Size::Small, &cfg,
            Location::Mobile, Arc::new(RustCompute), false,
        )
        .unwrap();
        let mut clone = build_process(
            &app, app.program(), Size::Small, &cfg,
            Location::Clone, Arc::new(RustCompute), true,
        )
        .unwrap();
        let po = run_monolithic(&mut phone).unwrap();
        let co = run_monolithic(&mut clone).unwrap();
        let speedup = po.virtual_ms / co.virtual_ms;
        assert!(
            speedup > 18.0 && speedup < 27.0,
            "max speedup {speedup} outside the paper's 19-21x band"
        );
        // Identical results on both devices.
        assert_eq!(
            read_static_int(&phone, "Scanner", "total"),
            read_static_int(&clone, "Scanner", "total")
        );
    }

    #[test]
    fn small_workload_lands_at_paper_scale() {
        // Paper: 100 KB on the phone = 5.70 s. Calibration target: same
        // order of magnitude (2-12 s band).
        let app = VirusScan;
        let cfg = Config {
            zygote_objects: 100,
            ..Config::default()
        };
        let mut p = build_process(
            &app, app.program(), Size::Small, &cfg,
            Location::Mobile, Arc::new(RustCompute), false,
        )
        .unwrap();
        let out = run_monolithic(&mut p).unwrap();
        let secs = out.virtual_ms / 1e3;
        assert!(secs > 2.0 && secs < 12.0, "100KB phone scan = {secs:.2}s");
    }
}
