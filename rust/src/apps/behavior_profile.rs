//! Behavior profiling / privacy-preserving targeted advertising
//! (paper §6, after Adnostic): tracks user interests on-device and maps
//! interest keyword vectors onto the DMOZ category hierarchy, computing
//! cosine similarity between user keywords and category keywords at
//! nesting depths 3-5.
//!
//! Classes: `AdsUI` (main + pinned UI), `Tracker` (the visit loop; holds
//! the category panel, user vectors, and browsing-history ballast),
//! `Similarity` (the everywhere compute native over the L1 Pallas
//! cosine kernel).

use std::sync::Arc;

use crate::util::lazy::Lazy;

use crate::appvm::assembler::assemble;
use crate::appvm::natives::shapes;
use crate::appvm::process::Process;
use crate::appvm::value::Value;
use crate::appvm::Program;
use crate::error::{CloneCloudError, Result};
use crate::util::rng::Rng;
use crate::vfs::SimFs;

use super::dmoz::{visits_for_depth, CategoryTree};
use super::workload::{behavior_depth, Size};
use super::{read_static_float, App};

const SRC: &str = r#"
class AdsUI app
  method main nargs=0 regs=4
    invokev AdsUI.uiinit
    invoke r0 Tracker.profile
    puts Tracker.best r0
    invokev AdsUI.show r0
    retv
  end
  method uiinit nargs=0 regs=0 native=ui.init
  method show nargs=1 regs=1 native=ui.show
end
class Tracker app
  static cats
  static users
  static hist
  static visits
  static best
  method profile nargs=0 regs=10
    gets r0 Tracker.visits
    gets r1 Tracker.users
    gets r2 Tracker.cats
    const r3 0
    constf r4 0.0
  vloop:
    ifge r3 r0 @done
    invoke r5 Similarity.categorize r1 r2
    # result: [best_idx_of_user0, best_score per user...]
    const r6 1
    aget r7 r5 r6
    fadd r4 r4 r7
    const r6 1
    add r3 r3 r6
    goto @vloop
  done:
    ret r4
  end
end
class Similarity app
  method categorize nargs=2 regs=2 native=compute.categorize
end
"#;

static PROGRAM: Lazy<Arc<Program>> = Lazy::new(|| {
    let p = assemble(SRC).expect("behavior profiling assembles");
    crate::appvm::verifier::verify_program(&p).expect("behavior profiling verifies");
    Arc::new(p)
});

/// The behavior-profiling app.
pub struct BehaviorProfile;

impl App for BehaviorProfile {
    fn name(&self) -> &'static str {
        "behavior"
    }

    fn input_label(&self, size: Size) -> String {
        format!("depth {}", behavior_depth(size))
    }

    fn program(&self) -> Arc<Program> {
        PROGRAM.clone()
    }

    fn make_fs(&self, _size: Size, _rng: &mut Rng) -> SimFs {
        // Browsing history lives in app state, not the fs.
        SimFs::new()
    }

    fn install(&self, p: &mut Process, size: Size, rng: &mut Rng) -> Result<()> {
        let depth = behavior_depth(size);
        let tree = CategoryTree::generate(depth, rng);
        let panel = tree.panel();
        // User interest vectors: biased toward a random category so the
        // best-score is meaningful.
        let target = rng.index(tree.nodes.len());
        let mut users = vec![0f32; shapes::N_USERS * shapes::KDIM];
        for u in 0..shapes::N_USERS {
            for k in 0..shapes::KDIM {
                users[u * shapes::KDIM + k] =
                    0.7 * tree.nodes[target].keywords[k] + 0.3 * rng.range_f32(-1.0, 1.0);
            }
        }
        let cid = p
            .program
            .class_id("Tracker")
            .ok_or_else(|| CloneCloudError::program("no Tracker class"))?;
        let class = p.program.class(cid);
        let cats_slot = class.static_id("cats").unwrap() as usize;
        let users_slot = class.static_id("users").unwrap() as usize;
        let hist_slot = class.static_id("hist").unwrap() as usize;
        let visits_slot = class.static_id("visits").unwrap() as usize;
        let arr_class = p.array_class;
        let cats_obj = p.heap.alloc_float_array(arr_class, panel);
        let users_obj = p.heap.alloc_float_array(arr_class, users);
        let mut hist = vec![0u8; 150 * 1024];
        rng.fill_bytes(&mut hist);
        let hist_obj = p.heap.alloc_byte_array(arr_class, hist);
        p.statics[cid.0 as usize][cats_slot] = Value::Ref(cats_obj);
        p.statics[cid.0 as usize][users_slot] = Value::Ref(users_obj);
        p.statics[cid.0 as usize][hist_slot] = Value::Ref(hist_obj);
        p.statics[cid.0 as usize][visits_slot] =
            Value::Int(visits_for_depth(depth) as i64);
        Ok(())
    }

    fn check(&self, p: &Process, size: Size) -> Result<String> {
        let best = read_static_float(p, "Tracker", "best")
            .ok_or_else(|| CloneCloudError::vm("no best score"))?;
        let visits = visits_for_depth(behavior_depth(size)) as f64;
        // Every visit scores the biased user against the panel: the sum
        // of best scores must be ~0.7-1.0 per visit.
        let per_visit = best / visits;
        if !(0.3..=1.01).contains(&per_visit) {
            return Err(CloneCloudError::vm(format!(
                "per-visit best score {per_visit:.3} implausible"
            )));
        }
        Ok(format!("best-category score sum {best:.1} over {visits} visits"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appvm::natives::RustCompute;
    use crate::apps::build_process;
    use crate::config::Config;
    use crate::device::Location;
    use crate::exec::run_monolithic;

    fn cfg() -> Config {
        Config {
            zygote_objects: 100,
            ..Config::default()
        }
    }

    #[test]
    fn depth3_monolithic_scores_plausibly() {
        let app = BehaviorProfile;
        let mut p = build_process(
            &app, app.program(), Size::Small, &cfg(),
            Location::Mobile, Arc::new(RustCompute), false,
        )
        .unwrap();
        let out = run_monolithic(&mut p).unwrap();
        app.check(&p, Size::Small).unwrap();
        // Paper: depth 3 on the phone = 3.6 s.
        let secs = out.virtual_ms / 1e3;
        assert!(secs > 1.5 && secs < 8.0, "depth-3 phone run = {secs:.2}s");
    }

    #[test]
    fn depth_scaling_matches_paper_ratios() {
        let app = BehaviorProfile;
        let mut times = Vec::new();
        for size in [Size::Small, Size::Medium] {
            let mut p = build_process(
                &app, app.program(), size, &cfg(),
                Location::Mobile, Arc::new(RustCompute), false,
            )
            .unwrap();
            let out = run_monolithic(&mut p).unwrap();
            times.push(out.virtual_ms);
        }
        let ratio = times[1] / times[0];
        assert!(
            (ratio - 13.0).abs() < 1.0,
            "depth4/depth3 = {ratio:.1} (paper: 13x)"
        );
    }
}
