//! Synthetic DMOZ open-directory substrate for the behavior-profiling
//! app (the paper uses the real DMOZ hierarchy, nesting levels 3-5).
//!
//! We generate a deterministic category tree whose nodes carry keyword
//! vectors, plus the page-visit trace the profiling app walks: the
//! number of categorization calls grows super-linearly with the depth
//! the app descends to, matching the paper's observed cost ratios
//! (3.6 s -> 46.8 s -> 315.8 s, i.e. 13x then 6.75x).

use crate::appvm::natives::shapes;
use crate::util::rng::Rng;

/// Categorization panel visits for a profiling run to DMOZ depth `d`.
///
/// Fitted to Table 1's behavior-profiling ratios: visits(3) = 73,
/// visits(4) = 13 x visits(3), visits(5) = 6.75 x visits(4) — the same
/// shape as the paper's depth-3/4/5 execution times (cost per visit is
/// depth-independent).
pub fn visits_for_depth(d: usize) -> usize {
    match d {
        0 => 1,
        1 => 8,
        2 => 24,
        3 => 73,
        4 => 949,
        5 => 6404,
        // Beyond the paper's range: keep the last observed growth rate.
        n => (6404.0 * 6.75f64.powi(n as i32 - 5)).round() as usize,
    }
}

/// A generated category node.
#[derive(Debug, Clone)]
pub struct Category {
    pub id: usize,
    pub depth: usize,
    pub parent: Option<usize>,
    /// Keyword vector (KDIM dims).
    pub keywords: Vec<f32>,
}

/// The synthetic directory tree.
#[derive(Debug, Clone)]
pub struct CategoryTree {
    pub nodes: Vec<Category>,
    pub fanout: usize,
    pub depth: usize,
}

impl CategoryTree {
    /// Generate a tree of the given depth with fanout 8 (capped at
    /// N_CATS total nodes so one panel holds the scored level).
    pub fn generate(depth: usize, rng: &mut Rng) -> CategoryTree {
        let fanout = 8;
        let mut nodes = vec![Category {
            id: 0,
            depth: 0,
            parent: None,
            keywords: keyword_vec(rng),
        }];
        let mut frontier = vec![0usize];
        for d in 1..=depth {
            let mut next = Vec::new();
            for &p in &frontier {
                for _ in 0..fanout {
                    if nodes.len() >= shapes::N_CATS {
                        break;
                    }
                    let id = nodes.len();
                    // Children share a bias of the parent's keywords so
                    // cosine walks are meaningful.
                    let mut kw = keyword_vec(rng);
                    for (k, pk) in kw.iter_mut().zip(&nodes[p].keywords) {
                        *k = 0.6 * *k + 0.4 * pk;
                    }
                    nodes.push(Category {
                        id,
                        depth: d,
                        parent: Some(p),
                        keywords: kw,
                    });
                    next.push(id);
                }
            }
            frontier = next;
        }
        CategoryTree {
            nodes,
            fanout,
            depth,
        }
    }

    /// Pack the tree into one (KDIM, N_CATS) category panel, column per
    /// node, zero columns as padding.
    pub fn panel(&self) -> Vec<f32> {
        let mut panel = vec![0f32; shapes::KDIM * shapes::N_CATS];
        for node in self.nodes.iter().take(shapes::N_CATS) {
            for k in 0..shapes::KDIM {
                panel[k * shapes::N_CATS + node.id] = node.keywords[k];
            }
        }
        panel
    }
}

fn keyword_vec(rng: &mut Rng) -> Vec<f32> {
    (0..shapes::KDIM).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visit_ratios_match_paper() {
        let v3 = visits_for_depth(3) as f64;
        let v4 = visits_for_depth(4) as f64;
        let v5 = visits_for_depth(5) as f64;
        assert!((v4 / v3 - 13.0).abs() < 0.1, "paper's 46.8/3.6 ratio");
        assert!((v5 / v4 - 6.75).abs() < 0.1, "paper's 315.8/46.8 ratio");
    }

    #[test]
    fn tree_structure() {
        let mut rng = Rng::new(5);
        let t = CategoryTree::generate(3, &mut rng);
        assert!(t.nodes.len() <= crate::appvm::natives::shapes::N_CATS);
        assert_eq!(t.nodes[0].depth, 0);
        assert!(t.nodes.iter().all(|n| n.depth <= 3));
        // Children reference valid parents at depth-1.
        for n in &t.nodes {
            if let Some(p) = n.parent {
                assert_eq!(t.nodes[p].depth, n.depth - 1);
            }
        }
    }

    #[test]
    fn panel_packs_columns() {
        let mut rng = Rng::new(6);
        let t = CategoryTree::generate(2, &mut rng);
        let panel = t.panel();
        use crate::appvm::natives::shapes::{KDIM, N_CATS};
        assert_eq!(panel.len(), KDIM * N_CATS);
        // Node 1's column equals its keywords.
        for k in 0..KDIM {
            assert_eq!(panel[k * N_CATS + 1], t.nodes[1].keywords[k]);
        }
        // Padding columns are zero.
        let last = N_CATS - 1;
        if t.nodes.len() < N_CATS {
            assert!((0..KDIM).all(|k| panel[k * N_CATS + last] == 0.0));
        }
    }

    #[test]
    fn generation_deterministic() {
        let a = CategoryTree::generate(3, &mut Rng::new(9)).panel();
        let b = CategoryTree::generate(3, &mut Rng::new(9)).panel();
        assert_eq!(a, b);
    }
}
