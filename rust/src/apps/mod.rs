//! The paper's three evaluation applications (§6), written in DroidVM
//! assembly with the same structure the paper describes, each split into
//! UI / driver / compute classes so method- and class-granularity
//! partitioners both have meaningful choices.
//!
//! Each app implements [`App`]: it supplies the program, generates its
//! workload (file system + installed static state) at one of three paper
//! input sizes, and can check the result of a run.

pub mod behavior_profile;
pub mod dmoz;
pub mod image_search;
pub mod virus_scan;
pub mod workload;

use std::sync::Arc;

use crate::appvm::natives::{ComputeBackend, NodeEnv};
use crate::appvm::process::Process;
use crate::appvm::value::Value;
use crate::appvm::zygote::build_template;
use crate::appvm::Program;
use crate::config::Config;
use crate::device::Location;
use crate::error::Result;
use crate::util::rng::Rng;
use crate::vfs::SimFs;

pub use behavior_profile::BehaviorProfile;
pub use image_search::ImageSearch;
pub use virus_scan::VirusScan;
pub use workload::Size;

/// A CloneCloud evaluation application.
pub trait App {
    /// Short name ("virus", "image", "behavior").
    fn name(&self) -> &'static str;
    /// Table 1's input-size label for a given size.
    fn input_label(&self, size: Size) -> String;
    /// The assembled (unmodified) program.
    fn program(&self) -> Arc<Program>;
    /// Generate the phone file system for a workload size.
    fn make_fs(&self, size: Size, rng: &mut Rng) -> SimFs;
    /// Install app state (static fields: signature panels, filter banks,
    /// category panels, caches). Must be deterministic in `rng`.
    fn install(&self, p: &mut Process, size: Size, rng: &mut Rng) -> Result<()>;
    /// Check a finished process's result; returns a human-readable
    /// result string, or an error if the run is wrong.
    fn check(&self, p: &Process, size: Size) -> Result<String>;
}

/// Build a ready-to-run process for an app on a device.
#[allow(clippy::too_many_arguments)]
pub fn build_process(
    app: &dyn App,
    program: Arc<Program>,
    size: Size,
    cfg: &Config,
    location: Location,
    backend: Arc<dyn ComputeBackend>,
    allow_pinned: bool,
) -> Result<Process> {
    let mut rng = Rng::new(cfg.seed);
    let fs = app.make_fs(size, &mut rng);
    let device = match location {
        Location::Mobile => cfg.phone.clone(),
        Location::Clone => cfg.clone.clone(),
    };
    let template = build_template(&program, cfg.zygote_objects, cfg.seed ^ 0x2760);
    let mut p = Process::fork_from_zygote(
        program,
        &template,
        device,
        location,
        NodeEnv::new(fs, backend),
    );
    p.cost_params = Some(cfg.costs.clone());
    p.allow_pinned = allow_pinned;
    // Same stream as make_fs: generators and installers derive shared
    // data (signature libraries, filter banks) from a common prefix.
    let mut rng2 = Rng::new(cfg.seed);
    app.install(&mut p, size, &mut rng2)?;
    Ok(p)
}

/// Read an integer static by qualified name (result extraction).
pub fn read_static_int(p: &Process, class: &str, name: &str) -> Option<i64> {
    let cid = p.program.class_id(class)?;
    let idx = p.program.class(cid).static_id(name)?;
    match p.statics[cid.0 as usize][idx as usize] {
        Value::Int(x) => Some(x),
        Value::Float(x) => Some(x as i64),
        _ => None,
    }
}

/// Read a float static by qualified name.
pub fn read_static_float(p: &Process, class: &str, name: &str) -> Option<f64> {
    let cid = p.program.class_id(class)?;
    let idx = p.program.class(cid).static_id(name)?;
    match p.statics[cid.0 as usize][idx as usize] {
        Value::Float(x) => Some(x),
        Value::Int(x) => Some(x as f64),
        _ => None,
    }
}

/// The three apps, boxed, for table-driven benches.
pub fn all_apps() -> Vec<Box<dyn App>> {
    vec![
        Box::new(VirusScan),
        Box::new(ImageSearch),
        Box::new(BehaviorProfile),
    ]
}
