//! Quickstart: the paper's Figure 5 worked end to end on a toy program.
//!
//! Builds a tiny app (a() -> {b() light, c() heavy}), profiles it on the
//! simulated phone and clone, solves the partitioning ILP, rewrites the
//! binary, and runs it distributed over an in-process clone — printing
//! every intermediate artifact (DC/TC relations, profile-tree residuals,
//! the chosen R(m) set, and the final speedup).
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::{NodeEnv, RustCompute};
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::config::{Config, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{run_distributed, run_monolithic, InlineClone};
use clonecloud::partitioner::{
    profile_run, rewrite_with_partition, solve_partition, Cfg, CostModel,
};
use clonecloud::vfs::SimFs;

/// Figure 5's program, with bodies: b() is light, c() is a heavy loop.
const SRC: &str = r#"
class C app
  static out
  method main nargs=0 regs=4
    invoke r0 C.a
    puts C.out r0
    retv
  end
  method a nargs=0 regs=4
    invoke r0 C.b
    invoke r1 C.c
    add r2 r0 r1
    ret r2
  end
  method b nargs=0 regs=4
    const r0 0
    const r1 100
    const r2 1
  loop:
    ifge r0 r1 @done
    add r0 r0 r2
    goto @loop
  done:
    ret r0
  end
  method c nargs=0 regs=4
    const r0 0
    const r1 400000
    const r2 1
  loop:
    ifge r0 r1 @done
    add r0 r0 r2
    goto @loop
  done:
    ret r0
  end
end
"#;

fn process(program: &Arc<clonecloud::appvm::Program>, dev: DeviceSpec, loc: Location) -> Process {
    let template = build_template(program, 500, 7);
    let mut p = Process::fork_from_zygote(
        program.clone(),
        &template,
        dev,
        loc,
        NodeEnv::with_rust_compute(SimFs::new()),
    );
    p.cost_params = Some(Config::default().costs);
    p
}

fn main() {
    let cfg = Config::default();
    let program = Arc::new(assemble(SRC).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let entry = program.entry().unwrap();

    // --- Static analysis (paper §3.1) -----------------------------------
    let cfg_graph = Cfg::build(&program);
    println!("== static analysis ==");
    for (i, j) in cfg_graph.dc_edges() {
        println!(
            "  DC: {} -> {}",
            program.method_name(cfg_graph.methods[i]),
            program.method_name(cfg_graph.methods[j])
        );
    }

    // --- Dynamic profiling (paper §3.2) ----------------------------------
    let mut phone = process(&program, cfg.phone.clone(), Location::Mobile);
    let (t_mobile, _) = profile_run(&mut phone, entry, &[], true).expect("phone profile");
    let mut clone = process(&program, cfg.clone.clone(), Location::Clone);
    let (t_clone, _) = profile_run(&mut clone, entry, &[], false).expect("clone profile");
    println!("\n== profile trees (method residuals, ms) ==");
    for m in program.app_methods() {
        println!(
            "  {:8}  mobile {:>10.2}  clone {:>8.2}  state {:>8}B",
            program.method_name(m),
            t_mobile.method_residual_us(m) / 1e3,
            t_clone.method_residual_us(m) / 1e3,
            t_mobile.method_state_bytes(m),
        );
    }

    // --- Optimization solving (paper §3.3) -------------------------------
    let net = NetworkProfile::wifi();
    let cost_model = CostModel::build_scaled(
        &[(&t_mobile, &t_clone)],
        &cfg.costs,
        &net,
        cfg.phone.cpu_factor,
        cfg.clone.cpu_factor,
    );
    let (partition, report) = solve_partition(&program, &cfg_graph, &cost_model).expect("solve");
    println!(
        "\n== partition ({} vars, {} constraints, {:.1}ms solve) ==",
        report.n_vars,
        report.n_constraints,
        report.solve_wall_s * 1e3
    );
    for &m in &partition.migrate {
        println!("  R(m)=1: {}", program.method_name(m));
    }
    println!(
        "  expected {:.1}ms vs local {:.1}ms",
        partition.expected_us / 1e3,
        partition.local_us / 1e3
    );

    // --- Distributed execution (paper §4) --------------------------------
    let mut mono = process(&program, cfg.phone.clone(), Location::Mobile);
    let mono_out = run_monolithic(&mut mono).expect("monolithic");

    let (rewritten, _) = rewrite_with_partition(&program, &partition).expect("rewrite");
    let rewritten = Arc::new(rewritten);
    let mut phone = process(&rewritten, cfg.phone.clone(), Location::Mobile);
    let clone = process(&rewritten, cfg.clone.clone(), Location::Clone);
    let mut channel = InlineClone::new(clone, cfg.costs.clone());
    let out = run_distributed(&mut phone, &mut channel, &net, &cfg.costs).expect("distributed");

    println!("\n== execution ==");
    println!("  monolithic (phone): {:>10.1}ms", mono_out.virtual_ms);
    println!(
        "  CloneCloud (WiFi):  {:>10.1}ms  ({} migration, {} objs shipped, {} zygote skipped)",
        out.virtual_ms, out.migrations, out.objects_shipped, out.zygote_skipped
    );
    println!("  speedup: {:.2}x", mono_out.virtual_ms / out.virtual_ms);
    assert_eq!(
        clonecloud::apps::read_static_int(&phone, "C", "out"),
        clonecloud::apps::read_static_int(&mono, "C", "out"),
        "distributed result equals monolithic result"
    );
    println!("  results match ✓");
    let _ = RustCompute;
}
