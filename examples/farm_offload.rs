//! Farm offload: 32 concurrent phone sessions against one clone farm.
//!
//! Each simulated phone has its own file system (distinct contents), runs
//! the partitioned synthetic workload under CloneCloud through a
//! [`FarmClone`] session, and checks its merged result **bit-identically**
//! against its own monolithic run. The farm serves all 32 phones from a
//! small worker pool with warm-pool provisioning, affinity placement, and
//! a bounded admission window — the demo prints the aggregate stats.
//!
//!     cargo run --release --example farm_offload

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::zygote::build_template;
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{run_distributed_policy, run_monolithic, Decision, PolicyEngine};
use clonecloud::farm::{
    synthetic_offload_src, CloneFarm, FarmConfig, PlacementPolicy,
};
use clonecloud::metrics::MetricsSnapshot;
use clonecloud::migration::MobileSession;
use clonecloud::util::rng::Rng;
use clonecloud::vfs::SimFs;

const PHONES: u64 = 32;
const ITERS: i64 = 30_000;
const ZYGOTE_OBJECTS: usize = 4_000;
const ZYGOTE_SEED: u64 = 0xFA12;

fn phone_fs(phone: u64) -> SimFs {
    let mut bytes = vec![0u8; 64];
    Rng::new(0xF5 ^ phone).fill_bytes(&mut bytes);
    let mut fs = SimFs::new();
    fs.add("data.bin", bytes);
    fs
}

fn phone_process(
    program: &Arc<clonecloud::appvm::Program>,
    template: &clonecloud::appvm::Heap,
    fs: SimFs,
) -> Process {
    Process::fork_from_zygote(
        program.clone(),
        template,
        DeviceSpec::phone_g1(),
        Location::Mobile,
        NodeEnv::with_rust_compute(fs),
    )
}

fn main() {
    let program = Arc::new(assemble(&synthetic_offload_src(ITERS)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let main_m = program.entry().unwrap();

    let farm = CloneFarm::start(
        program.clone(),
        FarmConfig {
            workers: 4,
            warm_per_worker: 2,
            queue_depth: 8, // < PHONES: admission backpressure is exercised
            policy: PlacementPolicy::Affinity,
            zygote_objects: ZYGOTE_OBJECTS,
            zygote_seed: ZYGOTE_SEED,
            fuel: 2_000_000_000,
            slot_gc_interval: 8,
        },
        CostParams::default(),
        Arc::new(NodeEnv::with_rust_compute),
    )
    .expect("farm start");
    let handle = farm.handle();
    // Phones boot the identical template independently (§4.3).
    let template = Arc::new(build_template(&program, ZYGOTE_OBJECTS, ZYGOTE_SEED));

    println!("== farm_offload: {PHONES} phones, 4 workers, affinity, queue 8 ==");
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for phone in 0..PHONES {
        let program = program.clone();
        let template = template.clone();
        let fs = phone_fs(phone);
        let mut session = handle.session(phone, fs.synchronize());
        joins.push(std::thread::spawn(move || {
            // Monolithic reference on this phone's own data.
            let mut mono = phone_process(&program, &template, fs.synchronize());
            run_monolithic(&mut mono).expect("monolithic");
            let expected = mono.statics[main_m.class.0 as usize][0]
                .as_int()
                .expect("mono result");

            // Distributed run through the farm, each phone driving its
            // own runtime policy engine (cold estimator: the static
            // partition choice offloads, then the measured wifi link
            // keeps winning).
            let mut p = phone_process(&program, &template, fs);
            let mut engine = PolicyEngine::auto();
            let out = run_distributed_policy(
                &mut p,
                &mut session,
                &NetworkProfile::wifi(),
                &CostParams::default(),
                &mut MobileSession::disabled(),
                &mut engine,
            )
            .expect("distributed");
            let got = p.statics[main_m.class.0 as usize][0]
                .as_int()
                .expect("merged result");
            assert_eq!(
                got, expected,
                "phone {phone}: farm result must be bit-identical to monolithic"
            );
            session.close();
            // Each invocation's decision + estimator state, logged next
            // to the session's negotiated (delta off, wifi) setup —
            // printed for the first phones only to keep output readable.
            if phone < 3 {
                for d in &engine.log {
                    println!(
                        "phone {phone} trip {} point {}: {} [{}]",
                        d.trip,
                        d.point,
                        match d.decision {
                            Decision::Offload => "OFFLOAD",
                            Decision::Local => "local",
                        },
                        d.estimator,
                    );
                }
                println!(
                    "phone {phone}: delta=off codec=none, estimator after run [{}]",
                    engine.estimator.describe()
                );
            }
            (out.migrations, session.stats.admission_wait_ms)
        }));
    }

    let mut migrations = 0;
    let mut admission_ms = 0.0;
    for j in joins {
        let (m, wait) = j.join().expect("phone session");
        migrations += m;
        admission_ms += wait;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(migrations, PHONES as usize, "one migration per phone");

    let stats = farm.shutdown();
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.sessions_closed, PHONES);
    println!(
        "all {PHONES} sessions completed with correct merged results ✓  \
         ({wall_s:.3}s wall, {:.1} sessions/s)",
        PHONES as f64 / wall_s
    );
    println!(
        "pool: {} hits / {} cold forks ({:.0}% hit), admission wait {:.1}ms total",
        stats.pool_hits,
        stats.pool_misses,
        stats.pool_hit_rate() * 100.0,
        admission_ms,
    );
    let mut m = MetricsSnapshot::default();
    m.absorb_farm(&stats);
    print!("{}", m.render());
}
