//! Delta migration end-to-end: repeat offloads ship only the dirty set,
//! results stay bit-identical to the full-capture path — including after
//! a forced baseline eviction (digest-mismatch fallback).
//!
//! Three runs of the same 12-round offload loop:
//!   1. full captures every roundtrip (the paper's original pipeline);
//!   2. delta capsules after first contact;
//!   3. delta capsules with the clone baseline evicted mid-session (as a
//!      recycled farm worker would), forcing a `NeedFull` fallback.
//! All three must produce identical application state, while (2) and (3)
//! ship a fraction of the bytes.
//!
//!     cargo run --example delta_offload

use std::sync::Arc;

use clonecloud::appvm::assembler::assemble;
use clonecloud::appvm::natives::NodeEnv;
use clonecloud::appvm::process::Process;
use clonecloud::appvm::value::ObjBody;
use clonecloud::appvm::zygote::build_template;
use clonecloud::appvm::{Heap, Program};
use clonecloud::config::{CostParams, NetworkProfile};
use clonecloud::device::{DeviceSpec, Location};
use clonecloud::exec::{
    delta_workload_expected, delta_workload_src, run_distributed_session, run_monolithic,
    InlineClone,
};
use clonecloud::migration::MobileSession;
use clonecloud::vfs::SimFs;

const ROUNDS: i64 = 12;
const PAYLOAD: i64 = 2_048;
const ZYGOTE_OBJECTS: usize = 500;
const ZYGOTE_SEED: u64 = 7;

fn make_proc(program: &Arc<Program>, template: &Heap, loc: Location) -> Process {
    let dev = match loc {
        Location::Mobile => DeviceSpec::phone_g1(),
        Location::Clone => DeviceSpec::clone_desktop(),
    };
    Process::fork_from_zygote(
        program.clone(),
        template,
        dev,
        loc,
        NodeEnv::with_rust_compute(SimFs::new()),
    )
}

/// The observable application state after a run: the `out` static and
/// the bytes of the clone-allocated `keep` array.
fn observable_state(program: &Arc<Program>, p: &Process) -> (i64, Vec<u8>) {
    let main = program.entry().unwrap();
    let out = p.statics[main.class.0 as usize][1].as_int().expect("out");
    let keep = p.statics[main.class.0 as usize][2]
        .as_ref()
        .expect("keep array");
    let bytes = match &p.heap.get(keep).unwrap().body {
        ObjBody::ByteArray(b) => b.clone(),
        other => panic!("keep should be a byte array, got {other:?}"),
    };
    (out, bytes)
}

struct RunReport {
    state: (i64, Vec<u8>),
    bytes: u64,
    delta_trips: usize,
    fallbacks: usize,
}

fn run(
    program: &Arc<Program>,
    template: &Heap,
    delta: bool,
    evict_mid_session: bool,
) -> RunReport {
    let mut phone = make_proc(program, template, Location::Mobile);
    let clone = make_proc(program, template, Location::Clone);
    let mut channel = InlineClone::new(clone, CostParams::default());
    if delta {
        channel = channel.with_delta();
    }
    let mut session = MobileSession::new(delta);
    let net = NetworkProfile::wifi();
    let costs = CostParams::default();

    // First pass of the offload loop.
    let out1 = run_distributed_session(&mut phone, &mut channel, &net, &costs, &mut session)
        .expect("first run");
    if evict_mid_session {
        // Simulate a recycled worker: the clone slot forgets the session
        // baseline while the phone still holds it. The next delta must be
        // rejected (`NeedFull`) and transparently resent in full.
        channel.evict_delta_baseline();
    }
    // Second pass reuses the same phone, channel, and session — the
    // repeat-offload scenario the baseline cache exists for.
    let out2 = run_distributed_session(&mut phone, &mut channel, &net, &costs, &mut session)
        .expect("second run");

    RunReport {
        state: observable_state(program, &phone),
        bytes: out1.transfer.up + out1.transfer.down + out2.transfer.up + out2.transfer.down,
        delta_trips: out1.delta_roundtrips + out2.delta_roundtrips,
        fallbacks: out1.delta_fallbacks + out2.delta_fallbacks,
    }
}

fn main() {
    let program = Arc::new(assemble(&delta_workload_src(ROUNDS, PAYLOAD)).expect("assemble"));
    clonecloud::appvm::verifier::verify_program(&program).expect("verify");
    let template = build_template(&program, ZYGOTE_OBJECTS, ZYGOTE_SEED);

    // Local reference: the partitioned binary with the "don't migrate"
    // policy.
    let mut local = make_proc(&program, &template, Location::Mobile);
    run_monolithic(&mut local).expect("local run");
    let local_state = observable_state(&program, &local);
    assert_eq!(local_state.0, delta_workload_expected(ROUNDS));

    let full = run(&program, &template, false, false);
    let delta = run(&program, &template, true, false);
    let evicted = run(&program, &template, true, true);

    assert_eq!(full.state, local_state, "full path matches local execution");
    assert_eq!(delta.state, full.state, "delta path is bit-identical");
    assert_eq!(
        evicted.state, full.state,
        "digest-mismatch fallback is bit-identical too"
    );

    assert_eq!(full.delta_trips, 0);
    assert!(
        delta.delta_trips as i64 >= 2 * ROUNDS - 1,
        "all repeat trips rode deltas ({} of {})",
        delta.delta_trips,
        2 * ROUNDS
    );
    assert_eq!(full.fallbacks, 0);
    assert_eq!(delta.fallbacks, 0);
    assert_eq!(evicted.fallbacks, 1, "eviction forced exactly one fallback");

    let ratio = full.bytes as f64 / delta.bytes as f64;
    println!(
        "local out={} | full {} B | delta {} B ({} delta trips, {ratio:.1}x fewer bytes) | \
         evicted {} B ({} fallback)",
        local_state.0, full.bytes, delta.bytes, delta.delta_trips, evicted.bytes,
        evicted.fallbacks
    );
    assert!(ratio >= 3.0, "two-run delta session saves bytes ({ratio:.2}x)");
    println!(
        "delta_offload: full, delta, and evicted-baseline runs all reached \
         bit-identical state; delta shipped {ratio:.1}x fewer capsule bytes"
    );
}
