//! Virus-scanner offload over a REAL TCP clone node.
//!
//! Spawns a clone node manager on a loopback TCP listener (its own
//! thread, its own PJRT runtime — the two "devices" share nothing but
//! the wire), provisions it (Zygote boot + executable hash check +
//! file-system synchronization), then runs the partitioned scanner on
//! the simulated phone: the scan loop migrates to the clone, scans the
//! synchronized files there with the AOT Pallas signature-match kernel,
//! and merges the verdict back.
//!
//!     cargo run --release --example virus_scan_offload

use std::path::Path;
use std::sync::Arc;

use clonecloud::apps::{build_process, App, Size, VirusScan};
use clonecloud::config::{Config, NetworkProfile};
use clonecloud::device::Location;
use clonecloud::exec::{run_distributed_policy, run_monolithic, Decision, PolicyEngine};
use clonecloud::migration::MobileSession;
use clonecloud::nodemanager::{CloneServer, NodeManager, TcpEndpoint, TcpTransport};
use clonecloud::partitioner::{rewrite_with_partition, PartitionEntry};
use clonecloud::pipeline::partition_app;
use clonecloud::runtime::default_backend;
use clonecloud::util::rng::Rng;

fn main() {
    let cfg = Config::default();
    let app = VirusScan;
    let size = Size::Medium; // 1 MB file system: Offload on WiFi (Table 1)
    let net = NetworkProfile::wifi();
    let backend = default_backend(Path::new(&cfg.artifacts_dir));

    // Offline: partition for the current conditions.
    let (partition, report) =
        partition_app(&app, size, &cfg, &net, &backend).expect("partitioning");
    println!(
        "partition for wifi: {} (profiled {} methods, solve {:.1}ms)",
        partition.label(),
        report.methods_profiled,
        report.solve_s * 1e3
    );
    let program = app.program();
    let (rewritten, _points) = rewrite_with_partition(&program, &partition).expect("rewrite");
    let rewritten = Arc::new(rewritten);

    // Runtime policy engine, priced from the partition-DB entry the
    // offline pipeline would store (per-span local/clone ms); the
    // rewritten binary itself maps method names to point ids.
    let entry = PartitionEntry::from_partition(app.name(), &net.name, &rewritten, &partition);
    let mut engine = PolicyEngine::auto();
    engine.load_entry(&entry, &rewritten).expect("span prices");

    // Clone node: own thread, own transport, own artifacts.
    let ep = TcpEndpoint::bind("127.0.0.1:0").expect("bind");
    let addr = ep.local_addr().unwrap();
    let server_prog = rewritten.clone();
    let costs = cfg.costs.clone();
    let artifacts = cfg.artifacts_dir.clone();
    let server = std::thread::spawn(move || {
        let t = ep.accept().expect("accept");
        let srv = CloneServer::new(
            t,
            server_prog,
            costs,
            Box::new(move |fs| {
                clonecloud::appvm::NodeEnv::new(fs, default_backend(Path::new(&artifacts)))
            }),
        );
        srv.serve().expect("clone serve")
    });

    // Phone side: node manager over TCP. Hello negotiation arms delta
    // capsules and the frame codec for the session (per-config).
    let mut nm = NodeManager::new(TcpTransport::connect(&addr).expect("connect"));
    let delta = cfg.delta_migration && nm.negotiate().expect("hello");
    // Log the negotiated capability set — in a mixed-version fleet this
    // line is how you tell which sessions ride deltas/compression.
    println!(
        "negotiated capability set: proto v{}, delta={}, codec={}",
        nm.negotiated_proto(),
        nm.delta_negotiated(),
        nm.negotiated_codec().name()
    );
    nm.provision(&rewritten, cfg.zygote_objects, cfg.seed ^ 0x2760)
        .expect("provision");
    let mut rng = Rng::new(cfg.seed);
    let fs = app.make_fs(size, &mut rng);
    let fs_bytes = nm.sync_fs(&fs).expect("fs sync");
    println!(
        "provisioned clone at {addr}; synchronized {fs_bytes} fs bytes; delta={delta}"
    );

    // Baseline: monolithic on the phone.
    let mut mono = build_process(
        &app, program.clone(), size, &cfg, Location::Mobile, backend.clone(), false,
    )
    .expect("mono process");
    let mono_out = run_monolithic(&mut mono).expect("monolithic");
    println!(
        "monolithic phone: {:.2}s virtual  ({})",
        mono_out.virtual_ms / 1e3,
        app.check(&mono, size).unwrap()
    );

    // CloneCloud run against the real remote clone.
    let mut phone = build_process(
        &app, rewritten.clone(), size, &cfg, Location::Mobile, backend, false,
    )
    .expect("phone process");
    let mut session = MobileSession::new(delta);
    if cfg.heartbeat_idle_ms > 0 {
        session.heartbeat_every(std::time::Duration::from_millis(cfg.heartbeat_idle_ms));
    }
    let out =
        run_distributed_policy(&mut phone, &mut nm, &net, &cfg.costs, &mut session, &mut engine)
            .expect("distributed");
    println!(
        "CloneCloud wifi:  {:.2}s virtual  ({})  [{} migration(s), {}B up / {}B down]",
        out.virtual_ms / 1e3,
        app.check(&phone, size).unwrap(),
        out.migrations,
        out.transfer.up,
        out.transfer.down
    );
    // Per-invocation policy decisions + estimator state, next to the
    // negotiated capability set printed above.
    for d in &engine.log {
        println!(
            "  policy trip {} point {}: {}{} local={} offload_est={}  [{}]",
            d.trip,
            d.point,
            match d.decision {
                Decision::Offload => "OFFLOAD",
                Decision::Local => "local",
            },
            if d.probe { " (probe)" } else { "" },
            d.local_ms
                .map_or_else(|| "?".to_string(), |x| format!("{x:.0}ms")),
            d.offload_est_ms
                .map_or_else(|| "?".to_string(), |x| format!("{x:.0}ms")),
            d.estimator,
        );
    }
    println!(
        "policy: {} offload / {} local decisions, {} misprediction(s); estimator now [{}]",
        out.offloads,
        out.local_fallbacks,
        out.mispredictions,
        engine.estimator.describe()
    );
    println!("speedup: {:.2}x", mono_out.virtual_ms / out.virtual_ms);

    nm.shutdown().expect("shutdown");
    let stats = server.join().unwrap();
    println!(
        "clone served {} migrations, {} instrs executed remotely",
        stats.migrations, stats.instrs_executed
    );
}
