//! Behavior profiling (Adnostic-style targeted advertising) under
//! changing network conditions — the paper's "different partitionings
//! for different inputs and networks" claim, exercised.
//!
//! Profiles the app once per input depth, then prices and solves the
//! partition for BOTH networks from the same profile trees, showing the
//! Local/Offload flips across the 3x2 condition grid, and runs the
//! chosen configuration each time.
//!
//!     cargo run --release --example behavior_profiling

use std::path::Path;

use clonecloud::apps::{App, BehaviorProfile, Size};
use clonecloud::config::{Config, NetworkProfile};
use clonecloud::pipeline::{clonecloud_cell_from_trees, monolithic_pair, profile_pair};
use clonecloud::runtime::default_backend;
use clonecloud::util::bench::Table;

fn main() {
    let cfg = Config::default();
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let app = BehaviorProfile;

    let mut t = Table::new(
        "Behavior profiling across inputs x networks",
        &["Input", "Phone(s)", "3G choice", "3G(s)", "WiFi choice", "WiFi(s)"],
    );

    for size in Size::all() {
        let program = app.program();
        let (tm, tc, _) =
            profile_pair(&app, &program, size, &cfg, &backend).expect("profiling");
        let trees = (tm, tc);
        let (po, _co, result) =
            monolithic_pair(&app, size, &cfg, &backend).expect("monolithic");
        let g = clonecloud_cell_from_trees(
            &app, &trees, size, &cfg, &NetworkProfile::threeg(), &backend, po.virtual_ms,
        )
        .expect("3g cell");
        let w = clonecloud_cell_from_trees(
            &app, &trees, size, &cfg, &NetworkProfile::wifi(), &backend, po.virtual_ms,
        )
        .expect("wifi cell");
        eprintln!("[behavior] {}: {result}", app.input_label(size));
        t.row(vec![
            app.input_label(size),
            format!("{:.2}", po.virtual_ms / 1e3),
            g.label.into(),
            format!("{:.2}", g.exec_ms / 1e3),
            w.label.into(),
            format!("{:.2}", w.exec_ms / 1e3),
        ]);
    }
    t.print();
    println!(
        "\nThe same binary late-binds to different partitions as conditions \
         change (paper §1: CloneCloud 'late-binds' the split)."
    );
}
