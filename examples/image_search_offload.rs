//! Image search with the partition DATABASE workflow (paper §3/§4):
//! partition once per execution condition, store the results in the
//! partition database, then at "launch time" look up the current
//! conditions and run whichever binary the DB prescribes.
//!
//!     cargo run --release --example image_search_offload

use std::path::Path;
use std::sync::Arc;

use clonecloud::apps::{build_process, App, ImageSearch, Size};
use clonecloud::config::{Config, NetworkProfile};
use clonecloud::device::Location;
use clonecloud::exec::{run_distributed, run_monolithic, InlineClone};
use clonecloud::partitioner::solver::Partition;
use clonecloud::partitioner::{rewrite_with_partition, PartitionDb, PartitionEntry};
use clonecloud::pipeline::{partition_from_trees, profile_pair};
use clonecloud::runtime::default_backend;

fn main() {
    let cfg = Config::default();
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let app = ImageSearch;
    let size = Size::Medium; // 10 images
    let program = app.program();

    // ---- Offline: fill the partition database --------------------------
    let (tm, tc, _) = profile_pair(&app, &program, size, &cfg, &backend).expect("profiling");
    let trees = (tm, tc);
    let mut db = PartitionDb::new();
    for net in [NetworkProfile::threeg(), NetworkProfile::wifi()] {
        let (partition, _, _) =
            partition_from_trees(&app, &trees, &cfg, &net).expect("solve");
        db.put(PartitionEntry::from_partition(
            app.name(),
            &net.name,
            &program,
            &partition,
        ));
    }
    let db_path = std::env::temp_dir().join("clonecloud_partitions.json");
    db.save(&db_path).expect("save db");
    println!("partition database written to {}:", db_path.display());
    for e in db.entries() {
        println!(
            "  ({}, {:>4}) -> {:8} migrate={:?} expected {:.1}s",
            e.app, e.network, e.label(), e.migrate, e.expected_ms / 1e3
        );
    }

    // ---- Online: launch under current conditions ------------------------
    let db = PartitionDb::load(&db_path).expect("load db");
    for net in [NetworkProfile::threeg(), NetworkProfile::wifi()] {
        let entry = db.lookup(app.name(), &net.name).expect("db entry");
        println!("\nlaunching under {} -> {}", net.name, entry.label());
        if entry.label() == "Local" {
            let mut p = build_process(
                &app, program.clone(), size, &cfg, Location::Mobile, backend.clone(), false,
            )
            .expect("process");
            let out = run_monolithic(&mut p).expect("run");
            println!(
                "  ran locally: {:.2}s virtual ({})",
                out.virtual_ms / 1e3,
                app.check(&p, size).unwrap()
            );
        } else {
            let migrate = entry.to_migrate_set(&program).expect("resolve");
            let partition = Partition {
                migrate,
                locations: Default::default(),
                expected_us: entry.expected_ms * 1e3,
                local_us: entry.local_ms * 1e3,
                span_costs: Default::default(),
            };
            let (rewritten, _) =
                rewrite_with_partition(&program, &partition).expect("rewrite");
            let rewritten = Arc::new(rewritten);
            let mut phone = build_process(
                &app, rewritten.clone(), size, &cfg, Location::Mobile, backend.clone(), false,
            )
            .expect("phone");
            let clone = build_process(
                &app, rewritten.clone(), size, &cfg, Location::Clone, backend.clone(), false,
            )
            .expect("clone");
            let mut channel = InlineClone::new(clone, cfg.costs.clone());
            let out = run_distributed(&mut phone, &mut channel, &net, &cfg.costs).expect("run");
            println!(
                "  ran offloaded: {:.2}s virtual, {} migration(s) ({})",
                out.virtual_ms / 1e3,
                out.migrations,
                app.check(&phone, size).unwrap()
            );
        }
    }
}
