//! END-TO-END DRIVER (DESIGN.md §6): the full CloneCloud system on a
//! real small workload, all layers composing.
//!
//! For the image-search application at every input size: generate the
//! photo corpus, profile on both simulated devices (executing the AOT
//! PJRT artifacts built from the L1 Pallas kernels), run static
//! analysis, solve the partitioning ILP for 3G and WiFi, rewrite the
//! binary, and execute the chosen configuration — distributed runs go
//! through a REAL loopback-TCP clone node with file synchronization.
//! Prints the paper-table rows plus the pipeline timing. Recorded in
//! EXPERIMENTS.md.
//!
//!     cargo run --release --example partition_explorer

use std::path::Path;
use std::sync::Arc;

use clonecloud::apps::{build_process, App, ImageSearch, Size};
use clonecloud::config::{Config, NetworkProfile};
use clonecloud::device::Location;
use clonecloud::exec::{run_distributed, run_monolithic};
use clonecloud::nodemanager::{CloneServer, NodeManager, TcpEndpoint, TcpTransport};
use clonecloud::partitioner::rewrite_with_partition;
use clonecloud::pipeline::{partition_from_trees, profile_pair};
use clonecloud::runtime::default_backend;
use clonecloud::util::bench::Table;
use clonecloud::util::rng::Rng;

fn main() {
    let cfg = Config::default();
    let backend = default_backend(Path::new(&cfg.artifacts_dir));
    let app = ImageSearch;

    let mut table = Table::new(
        "partition_explorer: image search, full pipeline, TCP clone node",
        &[
            "Input", "Phone(s)", "Clone(s)", "Net", "Choice", "CC(s)", "Speedup",
            "Migr", "Up", "Down", "Result",
        ],
    );

    for size in Size::all() {
        let program = app.program();
        // Profile once per input (network-independent).
        let t0 = std::time::Instant::now();
        let (tm, tc, rep) = profile_pair(&app, &program, size, &cfg, &backend).unwrap();
        let trees = (tm, tc);
        eprintln!(
            "[explorer] {}: profiled {} methods in {:.1}s wall",
            app.input_label(size),
            rep.methods_profiled,
            t0.elapsed().as_secs_f64()
        );

        // Monolithic columns.
        let mut phone = build_process(
            &app, program.clone(), size, &cfg, Location::Mobile, backend.clone(), false,
        )
        .unwrap();
        let po = run_monolithic(&mut phone).unwrap();
        let result = app.check(&phone, size).unwrap();
        let mut clone = build_process(
            &app, program.clone(), size, &cfg, Location::Clone, backend.clone(), true,
        )
        .unwrap();
        let co = run_monolithic(&mut clone).unwrap();

        for net in [NetworkProfile::threeg(), NetworkProfile::wifi()] {
            let (partition, _, _) =
                partition_from_trees(&app, &trees, &cfg, &net).unwrap();
            if !partition.is_offload() {
                table.row(vec![
                    app.input_label(size),
                    format!("{:.2}", po.virtual_ms / 1e3),
                    format!("{:.2}", co.virtual_ms / 1e3),
                    net.name.clone(),
                    "Local".into(),
                    format!("{:.2}", po.virtual_ms / 1e3),
                    "1.00".into(),
                    "0".into(),
                    "-".into(),
                    "-".into(),
                    result.clone(),
                ]);
                continue;
            }
            let (rewritten, _) = rewrite_with_partition(&program, &partition).unwrap();
            let rewritten = Arc::new(rewritten);

            // Real clone node over TCP.
            let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
            let addr = ep.local_addr().unwrap();
            let srv_prog = rewritten.clone();
            let costs = cfg.costs.clone();
            let artifacts = cfg.artifacts_dir.clone();
            let server = std::thread::spawn(move || {
                let t = ep.accept().unwrap();
                CloneServer::new(
                    t,
                    srv_prog,
                    costs,
                    Box::new(move |fs| {
                        clonecloud::appvm::NodeEnv::new(
                            fs,
                            default_backend(Path::new(&artifacts)),
                        )
                    }),
                )
                .serve()
                .unwrap()
            });
            let mut nm = NodeManager::new(TcpTransport::connect(&addr).unwrap());
            nm.provision(&rewritten, cfg.zygote_objects, cfg.seed ^ 0x2760)
                .unwrap();
            let mut rng = Rng::new(cfg.seed);
            nm.sync_fs(&app.make_fs(size, &mut rng)).unwrap();

            let mut cc_phone = build_process(
                &app, rewritten.clone(), size, &cfg, Location::Mobile, backend.clone(), false,
            )
            .unwrap();
            let out = run_distributed(&mut cc_phone, &mut nm, &net, &cfg.costs).unwrap();
            let cc_result = app.check(&cc_phone, size).unwrap();
            assert_eq!(cc_result, result, "distributed == monolithic result");
            nm.shutdown().unwrap();
            server.join().unwrap();

            table.row(vec![
                app.input_label(size),
                format!("{:.2}", po.virtual_ms / 1e3),
                format!("{:.2}", co.virtual_ms / 1e3),
                net.name.clone(),
                "Offload".into(),
                format!("{:.2}", out.virtual_ms / 1e3),
                format!("{:.2}", po.virtual_ms / out.virtual_ms),
                format!("{}", out.migrations),
                clonecloud::util::stats::fmt_bytes(out.transfer.up),
                clonecloud::util::stats::fmt_bytes(out.transfer.down),
                cc_result,
            ]);
        }
    }
    table.print();
    println!("\nAll distributed results matched their monolithic runs ✓");
}
